//! Packed, tiled, multi-threaded WAQ LUT-GEMM — the fast software backend,
//! width-generic over every packed stream the repo serves (2/3/4-bit).
//!
//! # Stream layout
//!
//! Weights arrive as [`PackedWeights`]: the K x N index matrix packed
//! `rows_per_byte` reduction rows per byte (2 at nibble widths, 4 at crumb
//! width), high-first, with the `n_rows % rows_per_byte` final rows kept
//! as column-packed [`crate::quant::PackedStream`] tails. Index traffic is
//! therefore 1/2 (nibble) or 1/4 (crumb) of the byte-per-index
//! `QuantWeights` form the direct path streams.
//!
//! # Fused pair-LUT
//!
//! For one token, reduction rows `2p` and `2p+1` use activation indices
//! `(ia0, ia1)`. Instead of two Cartesian-LUT gathers per output element,
//! build one fused row per pair once:
//!
//! ```text
//! lutF[b] = lut[ia0][b >> 4] + lut[ia1][b & 15]    (nibble widths)
//! lutF[c] = lut[ia0][c >> 2] + lut[ia1][c &  3]    (crumb width)
//! ```
//!
//! and then stream the packed weight bytes: each nibble byte costs a
//! single table lookup and a single accumulate for TWO MACs; each crumb
//! byte costs two fused-pair lookups for FOUR MACs. Because every fused
//! entry is exactly the `lut[ia0][iw0] + lut[ia1][iw1]` sum the direct
//! path computes before accumulating, every result here is bit-exact with
//! [`super::waq::execute_direct`] (same FP additions in the same order) at
//! every width.
//!
//! # Per-group scales
//!
//! When the weights carry a FineQuant per-group scale grid, each
//! `group_size`-row block accumulates into a zeroed per-group scratch and
//! is folded through its factor (`out += gacc * group_scale`) before the
//! per-token x per-column scaling — the same fold order as the direct
//! reference, so bit-exactness holds grouped or not. Group boundaries are
//! multiples of 4 (enforced at quantization), so a scale group never
//! splits a packed byte.
//!
//! # Tiling + threads
//!
//! [`execute_batch_tiled`] blocks over N (column ranges, one per worker
//! thread) and over K (chunk blocks), iterating tokens inside the K block
//! so a `k_pair_block x n_block`-byte weight tile is re-streamed from
//! cache — not memory — for every token of a continuous-batch decode
//! step. Workers own disjoint column ranges, so parallelism never changes
//! the per-output accumulation order: results are bit-exact for every
//! thread count and tile shape.

use super::lut::CartesianLut;
use crate::quant::{PackedWeights, QuantToken};

/// Tile/parallelism configuration for [`execute_batch_tiled`].
#[derive(Clone, Copy, Debug)]
pub struct TileCfg {
    /// Minimum column-range width per worker; also the amortization span
    /// of each fused-row build. Wider = less build overhead, narrower =
    /// more parallelism.
    pub n_block: usize,
    /// Reduction row-chunks per K tile (pairs at nibble widths, quads at
    /// crumb width); `k_pair_block * n_block` bytes of packed weights
    /// should sit comfortably in L2.
    pub k_pair_block: usize,
    /// Worker threads over column ranges; 0 = use available parallelism.
    pub threads: usize,
}

impl Default for TileCfg {
    fn default() -> Self {
        TileCfg { n_block: 512, k_pair_block: 128, threads: 0 }
    }
}

impl TileCfg {
    /// Single-threaded variant (bit-exact with every other setting; useful
    /// for deterministic-latency comparisons).
    pub fn single_thread() -> Self {
        TileCfg { threads: 1, ..Self::default() }
    }
}

/// Debug-only guard matching `execute_direct`'s fail-loudly index check: a
/// packed byte whose nibble exceeds the weight codebook means corrupt
/// index data (its fused-table slot is never written) and must not be
/// silently read as a stale/zero entry.
#[inline]
fn debug_assert_nibbles(b: u8, mask: usize) {
    debug_assert!(
        (b >> 4) as usize <= mask && (b & 0x0F) as usize <= mask,
        "packed weight byte {b:#04x} out of range for nibble mask {mask:#x}"
    );
}

/// Debug-only guard for the crumb stream, mirroring
/// [`debug_assert_nibbles`].
#[inline]
fn debug_assert_crumbs(b: u8, mask: usize) {
    debug_assert!(
        (0..4).all(|r| ((b >> (6 - 2 * r)) & 0x03) as usize <= mask),
        "packed weight byte {b:#04x} out of range for crumb mask {mask:#x}"
    );
}

/// Build the fused pair row: `fused[b] = lut[ia0][b >> 4] + lut[ia1][b & 15]`
/// for every byte value that can occur with in-range nibbles. Entries whose
/// nibbles exceed the weight codebook are never produced by
/// `PackedWeights` and are left untouched.
#[inline]
fn build_fused_row(fused: &mut [f32; 256], ia0: u8, ia1: u8, lut: &CartesianLut) {
    let mask = (1usize << lut.n_w_bits) - 1;
    let r0 = &lut.table[(ia0 as usize) << lut.n_w_bits..][..mask + 1];
    let r1 = &lut.table[(ia1 as usize) << lut.n_w_bits..][..mask + 1];
    for (hi, &v0) in r0.iter().enumerate() {
        let dst = &mut fused[hi << 4..(hi << 4) + mask + 1];
        for (d, &v1) in dst.iter_mut().zip(r1) {
            *d = v0 + v1;
        }
    }
}

/// Build a fused crumb-pair row for activation indices `(ia0, ia1)`:
/// `fused[(iw0 << 2) | iw1] = lut[ia0][iw0] + lut[ia1][iw1]` — the crumb
/// analogue of [`build_fused_row`], 16 entries instead of 256.
#[inline]
fn build_fused_crumb_pair(fused: &mut [f32; 16], ia0: u8, ia1: u8, lut: &CartesianLut) {
    let mask = (1usize << lut.n_w_bits) - 1;
    let r0 = &lut.table[(ia0 as usize) << lut.n_w_bits..][..mask + 1];
    let r1 = &lut.table[(ia1 as usize) << lut.n_w_bits..][..mask + 1];
    for (hi, &v0) in r0.iter().enumerate() {
        let dst = &mut fused[hi << 2..(hi << 2) + mask + 1];
        for (d, &v1) in dst.iter_mut().zip(r1) {
            *d = v0 + v1;
        }
    }
}

/// Accumulate the 1-3 tail rows exactly like the direct path: row pairs
/// first (one fused-pair lookup per column, matching the direct kernel's
/// two-row unroll — tail rows start at `body_rows()`, an even offset from
/// any group start, so the pairing boundary lines up), then a plain
/// LUT-row gather for a final odd row. Only 2-bit streams can have more
/// than one tail row, so the pair table uses crumb indexing.
fn add_tail(acc: &mut [f32], j0: usize, tok: &QuantToken, w: &PackedWeights, lut: &CartesianLut) {
    let base_k = w.body_rows();
    let mask = (1usize << lut.n_w_bits) - 1;
    let mut fused = [0.0f32; 16];
    let mut t = 0;
    while t + 1 < w.tail.len() {
        build_fused_crumb_pair(&mut fused, tok.idx[base_k + t], tok.idx[base_k + t + 1], lut);
        let (r0, r1) = (&w.tail[t], &w.tail[t + 1]);
        for (jj, a) in acc.iter_mut().enumerate() {
            let (i0, i1) = (r0.get(j0 + jj) as usize, r1.get(j0 + jj) as usize);
            debug_assert!(i0 <= mask && i1 <= mask, "tail index {i0}/{i1} out of range");
            *a += fused[(i0 << 2) | i1];
        }
        t += 2;
    }
    if t < w.tail.len() {
        let base = (tok.idx[base_k + t] as usize) << lut.n_w_bits;
        let row = &lut.table[base..base + mask + 1];
        let tail = &w.tail[t];
        for (jj, a) in acc.iter_mut().enumerate() {
            let iw = tail.get(j0 + jj) as usize;
            debug_assert!(iw <= mask, "tail weight index {iw} out of range (mask {mask})");
            *a += row[iw & mask];
        }
    }
}

/// Accumulate (no scaling beyond group folding) reduction rows `[k0, k1)`
/// of columns `[j0, j1)` for every token, dispatching on the stream
/// density. K-chunk tiles are outermost with tokens inside, so each packed
/// weight tile is reused across the whole batch while hot. Tail rows are
/// processed iff `k1` reaches past the body.
#[allow(clippy::too_many_arguments)]
fn accumulate_rows(
    toks: &[QuantToken],
    w: &PackedWeights,
    lut: &CartesianLut,
    k_block: usize,
    k0: usize,
    k1: usize,
    j0: usize,
    j1: usize,
    outs: &mut [&mut [f32]],
) {
    let n = w.n_cols;
    let per = w.rows_per_byte();
    let width = j1 - j0;
    let body_rows = w.body_rows();
    // group starts are multiples of 4 and the body spans a whole number of
    // chunks, so both bounds land on chunk boundaries
    let c0 = k0 / per;
    let c1 = k1.min(body_rows) / per;
    let mask = (1usize << lut.n_w_bits) - 1;
    let mut cb = c0;
    if per == 2 {
        let mut fused = [0.0f32; 256];
        while cb < c1 {
            let ce = (cb + k_block).min(c1);
            for (tok, acc) in toks.iter().zip(outs.iter_mut()) {
                for p in cb..ce {
                    build_fused_row(&mut fused, tok.idx[2 * p], tok.idx[2 * p + 1], lut);
                    let wrow = &w.body[p * n + j0..p * n + j1];
                    for (a, &b) in acc[..width].iter_mut().zip(wrow) {
                        debug_assert_nibbles(b, mask);
                        *a += fused[b as usize];
                    }
                }
            }
            cb = ce;
        }
    } else {
        // each crumb byte is two fused-pair lookups for FOUR MACs — the
        // same per-column add sequence as the direct path's two-row unroll
        let mut fhi = [0.0f32; 16];
        let mut flo = [0.0f32; 16];
        while cb < c1 {
            let ce = (cb + k_block).min(c1);
            for (tok, acc) in toks.iter().zip(outs.iter_mut()) {
                for q in cb..ce {
                    build_fused_crumb_pair(&mut fhi, tok.idx[4 * q], tok.idx[4 * q + 1], lut);
                    build_fused_crumb_pair(&mut flo, tok.idx[4 * q + 2], tok.idx[4 * q + 3], lut);
                    let wrow = &w.body[q * n + j0..q * n + j1];
                    for (a, &b) in acc[..width].iter_mut().zip(wrow) {
                        debug_assert_crumbs(b, mask);
                        *a += fhi[(b >> 4) as usize];
                        *a += flo[(b & 0x0F) as usize];
                    }
                }
            }
            cb = ce;
        }
    }
    if k1 > body_rows {
        for (tok, acc) in toks.iter().zip(outs.iter_mut()) {
            add_tail(&mut acc[..width], j0, tok, w, lut);
        }
    }
}

/// Accumulate columns `[j0, j1)` of every token into `outs[t][..j1-j0]`.
/// Ungrouped weights accumulate straight into the outputs; grouped
/// weights accumulate each scale group into a zeroed scratch and fold it
/// through the group factor, exactly like the direct reference. In both
/// cases the caller applies the per-token x per-column scaling afterwards.
fn accumulate_range(
    toks: &[QuantToken],
    w: &PackedWeights,
    lut: &CartesianLut,
    k_block: usize,
    j0: usize,
    j1: usize,
    outs: &mut [&mut [f32]],
) {
    if w.group_scales.is_empty() {
        accumulate_rows(toks, w, lut, k_block, 0, w.n_rows, j0, j1, outs);
        return;
    }
    let width = j1 - j0;
    let mut scratch: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; width]).collect();
    for g in 0..w.n_groups() {
        let (k0, k1) = w.group_bounds(g);
        for ga in scratch.iter_mut() {
            ga.fill(0.0);
        }
        {
            let mut views: Vec<&mut [f32]> =
                scratch.iter_mut().map(Vec::as_mut_slice).collect();
            accumulate_rows(toks, w, lut, k_block, k0, k1, j0, j1, &mut views);
        }
        let gs = &w.group_scales[g * w.n_cols + j0..g * w.n_cols + j1];
        for (acc, ga) in outs.iter_mut().zip(&scratch) {
            for ((a, &v), &s) in acc[..width].iter_mut().zip(ga).zip(gs) {
                *a += v * s;
            }
        }
    }
}

/// Accumulate (unscaled output; group factors already folded) the full
/// column range of `w` for every token into per-token output slices (each
/// at least `w.n_cols` long), K-chunk tiles outermost. Per output column
/// the accumulation order is identical to [`execute_batch_tiled`]'s — k
/// pairs ascending within each scale group, then the tail — for every
/// `k_pair_block` and stream width, so callers that scale afterwards stay
/// bit-exact with the unsharded kernel. This is the building block the
/// tensor-parallel sharded backend (`gemm::sharded`) drives with each
/// shard's column slice of the packed weights.
pub fn accumulate_tiles(
    toks: &[QuantToken],
    w: &PackedWeights,
    lut: &CartesianLut,
    k_pair_block: usize,
    outs: &mut [&mut [f32]],
) {
    for t in toks {
        assert_eq!(t.idx.len(), w.n_rows, "reduction length mismatch");
    }
    assert_eq!(toks.len(), outs.len(), "token/output arity mismatch");
    accumulate_range(toks, w, lut, k_pair_block.max(1), 0, w.n_cols, outs);
}

/// Single-token packed GEMM: `out[n] = a_scale * w_scale[n] *
/// sum_k LUT[cat(a_idx[k], w_idx[k, n])]`, bit-exact with
/// `execute_direct` at every stream width, at 1/2 (nibble) or 1/4 (crumb)
/// of the index traffic.
pub fn execute_packed(tok: &QuantToken, w: &PackedWeights, lut: &CartesianLut) -> Vec<f32> {
    assert_eq!(tok.idx.len(), w.n_rows, "reduction length mismatch");
    let n = w.n_cols;
    let mut out = vec![0.0f32; n];
    {
        let mut views = [out.as_mut_slice()];
        accumulate_range(
            std::slice::from_ref(tok),
            w,
            lut,
            w.n_chunks().max(1),
            0,
            n,
            &mut views,
        );
    }
    for (j, a) in out.iter_mut().enumerate() {
        *a *= tok.scale * w.col_scales[j];
    }
    out
}

/// Split `[0, n)` into `parts` contiguous near-equal ranges (width
/// `ceil(n / parts)`, last range truncated, empty ranges dropped). The
/// ONE chunking definition shared by the tiled kernel's per-thread column
/// ranges and the sharded backend's load-time column split
/// (`gemm::sharded`), so the two paths can never split columns
/// differently.
pub(crate) fn even_ranges(n: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.max(1);
    let width = n.div_ceil(parts);
    (0..parts)
        .map(|i| (i * width, ((i + 1) * width).min(n)))
        .filter(|&(j0, j1)| j0 < j1)
        .collect()
}

/// Split `[0, n)` into per-worker column ranges: at most `threads` ranges,
/// each at least `n_block` wide (so fused-row builds stay amortized).
fn col_ranges(n: usize, cfg: &TileCfg) -> Vec<(usize, usize)> {
    let hw = if cfg.threads > 0 {
        cfg.threads
    } else {
        std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
    };
    let min_width = cfg.n_block.max(1);
    let t = hw.clamp(1, (n / min_width).max(1));
    even_ranges(n, t)
}

/// Multi-token (M x K) @ (K x N) over packed weights of any stream width:
/// cache-tiled over N and K with the weight tile reused across every token
/// of the batch, and column ranges fanned out over scoped worker threads.
/// Bit-exact with per-token `execute_direct` for every tile shape, thread
/// count, stream width, and scale-group size.
pub fn execute_batch_tiled(
    toks: &[QuantToken],
    w: &PackedWeights,
    lut: &CartesianLut,
    cfg: &TileCfg,
) -> Vec<Vec<f32>> {
    for t in toks {
        assert_eq!(t.idx.len(), w.n_rows, "reduction length mismatch");
    }
    if toks.is_empty() {
        return Vec::new();
    }
    let n = w.n_cols;
    let k_block = cfg.k_pair_block.max(1);
    let ranges = col_ranges(n, cfg);
    let mut out: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; n]).collect();

    if ranges.len() <= 1 {
        let mut views: Vec<&mut [f32]> = out.iter_mut().map(Vec::as_mut_slice).collect();
        accumulate_range(toks, w, lut, k_block, 0, n, &mut views);
    } else {
        std::thread::scope(|s| {
            let workers: Vec<_> = ranges
                .iter()
                .map(|&(j0, j1)| {
                    s.spawn(move || {
                        let mut local: Vec<Vec<f32>> =
                            toks.iter().map(|_| vec![0.0f32; j1 - j0]).collect();
                        let mut views: Vec<&mut [f32]> =
                            local.iter_mut().map(Vec::as_mut_slice).collect();
                        accumulate_range(toks, w, lut, k_block, j0, j1, &mut views);
                        drop(views);
                        (j0, local)
                    })
                })
                .collect();
            for worker in workers {
                let (j0, local) = worker.join().expect("waq gemm worker panicked");
                for (dst, src) in out.iter_mut().zip(local) {
                    dst[j0..j0 + src.len()].copy_from_slice(&src);
                }
            }
        });
    }

    // per-token x per-channel scaling, after all accumulation — the same
    // grouping as the direct path
    for (tok, row) in toks.iter().zip(out.iter_mut()) {
        for (j, a) in row.iter_mut().enumerate() {
            *a *= tok.scale * w.col_scales[j];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemm::waq;
    use crate::quant::{self, OutlierCfg, QuantWeights};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn setup_grouped(
        seed: u64,
        k: usize,
        n: usize,
        a_bits: u32,
        w_bits: u32,
        group: usize,
        batch: usize,
    ) -> (Vec<QuantToken>, QuantWeights, CartesianLut) {
        let mut rng = Rng::new(seed);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights_grouped(&wmat, None, w_bits, group);
        let calib: Vec<Vec<f32>> =
            (0..6).map(|_| rng.heavy_tailed_vec(k, 0.02, 10.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg { total_frac: 0.03 };
        let cb_a = quant::learn_act_codebook(&refs, None, a_bits, cfg);
        let toks: Vec<QuantToken> = (0..batch)
            .map(|_| quant::quantize_token(&rng.heavy_tailed_vec(k, 0.02, 10.0), &cb_a, cfg))
            .collect();
        let lut = CartesianLut::build(&cb_a, &qw.codebook);
        (toks, qw, lut)
    }

    fn setup(
        seed: u64,
        k: usize,
        n: usize,
        a_bits: u32,
        w_bits: u32,
        batch: usize,
    ) -> (Vec<QuantToken>, QuantWeights, CartesianLut) {
        setup_grouped(seed, k, n, a_bits, w_bits, 0, batch)
    }

    #[test]
    fn packed_bit_exact_with_direct_every_width() {
        // even and odd K, including tail-only edges for both densities
        for w_bits in [2u32, 3, 4] {
            for &(k, n) in &[(64usize, 24usize), (65, 24), (66, 17), (67, 9), (1, 8), (3, 8)] {
                let (toks, qw, lut) = setup(10 + k as u64 + w_bits as u64, k, n, 4, w_bits, 1);
                let pw = qw.pack();
                let direct = waq::execute_direct(&toks[0], &qw, &lut);
                let packed = execute_packed(&toks[0], &pw, &lut);
                assert_eq!(packed, direct, "({k},{n}) W{w_bits} not bit-exact");
            }
        }
    }

    #[test]
    fn packed_bit_exact_mixed_bitwidths() {
        // 3-bit activations x {4,3,2}-bit weights and 4x3
        for &(ab, wb) in &[(3u32, 4u32), (4, 3), (3, 3), (3, 2)] {
            let (toks, qw, lut) = setup(77 + ab as u64 + wb as u64, 96, 20, ab, wb, 1);
            let pw = qw.pack();
            let direct = waq::execute_direct(&toks[0], &qw, &lut);
            let packed = execute_packed(&toks[0], &pw, &lut);
            assert_eq!(packed, direct, "A{ab}/W{wb} not bit-exact");
        }
    }

    #[test]
    fn tiled_bit_exact_across_tiles_and_threads() {
        for w_bits in [2u32, 3, 4] {
            let (toks, qw, lut) = setup(5 + w_bits as u64, 97, 41, 4, w_bits, 5);
            let pw = qw.pack();
            let want: Vec<Vec<f32>> =
                toks.iter().map(|t| waq::execute_direct(t, &qw, &lut)).collect();
            for threads in [1usize, 2, 3, 8] {
                for (nb, kb) in [(8usize, 3usize), (16, 1), (512, 128), (5, 1000)] {
                    let cfg = TileCfg { n_block: nb, k_pair_block: kb, threads };
                    let got = execute_batch_tiled(&toks, &pw, &lut, &cfg);
                    assert_eq!(got, want, "W{w_bits} threads={threads} nb={nb} kb={kb}");
                }
            }
        }
    }

    #[test]
    fn grouped_tiled_bit_exact_with_direct() {
        // per-group scales at every width, ragged final groups, tail rows
        // landing inside the final group
        for w_bits in [2u32, 3, 4] {
            for &(k, n) in &[(64usize, 24usize), (70, 17), (33, 12)] {
                for group in [4usize, 32] {
                    let (toks, qw, lut) =
                        setup_grouped(60 + k as u64 + w_bits as u64, k, n, 4, w_bits, group, 4);
                    let pw = qw.pack();
                    let want: Vec<Vec<f32>> =
                        toks.iter().map(|t| waq::execute_direct(t, &qw, &lut)).collect();
                    for threads in [1usize, 3] {
                        for (nb, kb) in [(8usize, 3usize), (512, 128)] {
                            let cfg = TileCfg { n_block: nb, k_pair_block: kb, threads };
                            let got = execute_batch_tiled(&toks, &pw, &lut, &cfg);
                            assert_eq!(
                                got, want,
                                "({k},{n}) W{w_bits} g{group} threads={threads} nb={nb} kb={kb}"
                            );
                        }
                    }
                    let single = execute_packed(&toks[0], &pw, &lut);
                    assert_eq!(single, want[0], "({k},{n}) W{w_bits} g{group} single-token");
                }
            }
        }
    }

    #[test]
    fn tiled_handles_empty_and_single() {
        let (toks, qw, lut) = setup(6, 32, 8, 4, 4, 1);
        let pw = qw.pack();
        let none: Vec<QuantToken> = Vec::new();
        assert!(execute_batch_tiled(&none, &pw, &lut, &TileCfg::default()).is_empty());
        let got = execute_batch_tiled(&toks, &pw, &lut, &TileCfg::default());
        assert_eq!(got[0], execute_packed(&toks[0], &pw, &lut));
    }

    #[test]
    fn accumulate_tiles_is_the_unscaled_kernel() {
        // the slice-level entry point the sharded backend drives: after
        // applying the same per-token/per-column scaling, it equals the
        // full batched kernel bit-for-bit (odd K exercises the tail row,
        // both stream densities covered)
        for w_bits in [2u32, 4] {
            let (toks, qw, lut) = setup(8 + w_bits as u64, 33, 12, 4, w_bits, 3);
            let pw = qw.pack();
            let mut rows: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; 12]).collect();
            let mut views: Vec<&mut [f32]> = rows.iter_mut().map(Vec::as_mut_slice).collect();
            accumulate_tiles(&toks, &pw, &lut, 4, &mut views);
            drop(views);
            for (tok, row) in toks.iter().zip(rows.iter_mut()) {
                for (a, &s) in row.iter_mut().zip(&pw.col_scales) {
                    *a *= tok.scale * s;
                }
            }
            let want = execute_batch_tiled(&toks, &pw, &lut, &TileCfg::single_thread());
            assert_eq!(rows, want, "W{w_bits}");
        }
    }

    #[test]
    fn crumb_kernel_mixed_activation_bits() {
        // 3-bit activations x 2-bit weights (the draft model pairs a 2-bit
        // weight codebook with whatever activation width the mode sets)
        for ab in [3u32, 4] {
            let (toks, qw, lut) = setup(90 + ab as u64, 48, 12, ab, 2, 2);
            let cw = qw.pack();
            let want: Vec<Vec<f32>> =
                toks.iter().map(|t| waq::execute_direct(t, &qw, &lut)).collect();
            let got = execute_batch_tiled(&toks, &cw, &lut, &TileCfg::default());
            assert_eq!(got, want, "A{ab}/W2 not bit-exact");
        }
    }

    #[test]
    fn fused_crumb_pair_matches_two_lookups() {
        let mut rng = Rng::new(92);
        let cb_a = quant::Codebook::new(rng.normal_vec(16, 1.0));
        let cb_w = quant::Codebook::new(rng.normal_vec(4, 1.0));
        let lut = CartesianLut::build(&cb_a, &cb_w);
        let mut fused = [0.0f32; 16];
        build_fused_crumb_pair(&mut fused, 5, 11, &lut);
        for iw0 in 0..4u8 {
            for iw1 in 0..4u8 {
                let b = ((iw0 as usize) << 2) | iw1 as usize;
                assert_eq!(fused[b], lut.lookup(5, iw0) + lut.lookup(11, iw1));
            }
        }
    }

    #[test]
    fn fused_row_matches_two_lookups() {
        let mut rng = Rng::new(9);
        let cb_a = quant::Codebook::new(rng.normal_vec(16, 1.0));
        let cb_w = quant::Codebook::new(rng.normal_vec(16, 1.0));
        let lut = CartesianLut::build(&cb_a, &cb_w);
        let mut fused = [0.0f32; 256];
        build_fused_row(&mut fused, 5, 11, &lut);
        for iw0 in 0..16u8 {
            for iw1 in 0..16u8 {
                let b = ((iw0 as usize) << 4) | iw1 as usize;
                assert_eq!(fused[b], lut.lookup(5, iw0) + lut.lookup(11, iw1));
            }
        }
    }
}
