//! The paper's GEMM schemes in software: the Cartesian-product LUT, the
//! WAQ LUT-GEMM main branch (bit-exact Index-Counter semantics), the
//! outlier branch (look-ahead + error compensation), and the WOQ
//! inner-product-LUT baseline family.

pub mod compensation;
pub mod lut;
pub mod waq;
pub mod woq;

pub use compensation::{compensate, execute_critical_path, execute_dual_branch};
pub use lut::CartesianLut;
pub use waq::{execute_direct, execute_histogram};
