//! The paper's GEMM schemes in software: the Cartesian-product LUT, the
//! WAQ LUT-GEMM main branch (bit-exact Index-Counter semantics), the
//! outlier branch (look-ahead + error compensation), the WOQ
//! inner-product-LUT baseline family, and the packed/tiled/threaded fast
//! backend (`packed`: any-bit packed indices + fused pair-LUT — see its
//! module docs for the byte layouts and the `lutF[b] = lut[ia0][b >> 4] +
//! lut[ia1][b & 15]` scheme).
//!
//! Execution-path selection goes through [`WaqBackend`] / [`WaqGemm`]:
//! `Direct` and `Histogram` are the numerics twins of the OASIS datapath
//! (kept for cross-checking and for the simulator's semantics), `Packed`
//! is the serving default. All three are bit-exact for in-range indices.
//! The `sharded` module adds tensor-parallel column sharding on top of
//! the packed form ([`ShardedWaqGemm`] on a persistent [`ShardPool`]),
//! bit-exact with the unsharded kernel at every shard count.

pub mod compensation;
pub mod lut;
pub mod packed;
pub mod sharded;
pub mod waq;
pub mod woq;

pub use compensation::{
    compensate, compensate_packed, execute_critical_path, execute_dual_branch,
};
pub use lut::CartesianLut;
pub use packed::{accumulate_tiles, execute_batch_tiled, execute_packed, TileCfg};
pub use sharded::{ShardPool, ShardedWaqGemm};
pub use waq::{execute_direct, execute_histogram};

use crate::quant::{PackedWeights, QuantToken, QuantWeights};

/// Which software execution path runs the WAQ LUT-GEMM.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WaqBackend {
    /// Per-element LUT gathers over byte-per-index storage.
    Direct,
    /// Literal Index-Counter semantics (histogram + MAC tree).
    Histogram,
    /// Any-bit packed fused pair-LUT kernel, tiled + threaded for batches.
    #[default]
    Packed,
}

impl WaqBackend {
    pub const ALL: [WaqBackend; 3] =
        [WaqBackend::Direct, WaqBackend::Histogram, WaqBackend::Packed];

    /// Canonical CLI/bench name (thin alias of the `Display` impl).
    pub fn name(&self) -> &'static str {
        match self {
            WaqBackend::Direct => "direct",
            WaqBackend::Histogram => "histogram",
            WaqBackend::Packed => "packed",
        }
    }

    /// Thin alias of the `FromStr` impl for call sites that prefer an
    /// `Option`.
    pub fn parse(s: &str) -> Option<WaqBackend> {
        s.parse().ok()
    }
}

impl std::fmt::Display for WaqBackend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for WaqBackend {
    type Err = String;

    fn from_str(s: &str) -> Result<WaqBackend, String> {
        WaqBackend::ALL
            .into_iter()
            .find(|b| b.name() == s)
            .ok_or_else(|| format!("unknown WAQ backend '{s}'"))
    }
}

/// Weight storage matching the backend that will stream it: the packed
/// backend drops the byte-per-index form entirely (keeping both would
/// cost extra index memory the packing exists to shrink). The packed form
/// picks its stream density from the codebook width — <= 2-bit codebooks
/// pack four reduction rows per byte (the speculative draft model's
/// regime), wider ones pack two.
enum WaqWeights {
    Unpacked(QuantWeights),
    Packed(PackedWeights),
}

/// A prepared WAQ GEMM: quantized weights (in backend-appropriate
/// storage) + LUT + backend choice. This is the software dispatch point:
/// the benches and the `kllm serve --backend` flag select through
/// [`WaqBackend`] — `coordinator::backend::NativeWaqBackend` executes its
/// serving decode through `execute_batch`, while the PJRT path mirrors
/// the same choice in a modeled host clock (`baselines::cpu::CpuWaqModel`).
pub struct WaqGemm {
    pub backend: WaqBackend,
    pub lut: CartesianLut,
    pub tile: TileCfg,
    w: WaqWeights,
}

impl WaqGemm {
    pub fn new(w: QuantWeights, lut: CartesianLut, backend: WaqBackend) -> WaqGemm {
        let w = match backend {
            WaqBackend::Packed => WaqWeights::Packed(w.pack()),
            _ => WaqWeights::Unpacked(w),
        };
        WaqGemm { backend, lut, tile: TileCfg::default(), w }
    }

    pub fn with_tile(mut self, tile: TileCfg) -> WaqGemm {
        self.tile = tile;
        self
    }

    /// The packed weight form (present iff the backend is `Packed`; its
    /// `bits()` reports the stream width, 2/3/4).
    pub fn packed_weights(&self) -> Option<&PackedWeights> {
        match &self.w {
            WaqWeights::Packed(p) => Some(p),
            _ => None,
        }
    }

    /// The byte-per-index weight form (present iff the backend is not
    /// `Packed`); the outlier-compensation branch fetches dequantized rows
    /// from whichever form is resident.
    pub fn unpacked_weights(&self) -> Option<&QuantWeights> {
        match &self.w {
            WaqWeights::Unpacked(w) => Some(w),
            _ => None,
        }
    }

    /// One-token decode GEMM on the selected backend.
    pub fn execute(&self, tok: &QuantToken) -> Vec<f32> {
        match (&self.w, self.backend) {
            (WaqWeights::Unpacked(w), WaqBackend::Direct) => {
                waq::execute_direct(tok, w, &self.lut)
            }
            (WaqWeights::Unpacked(w), WaqBackend::Histogram) => {
                waq::execute_histogram(tok, w, &self.lut)
            }
            (WaqWeights::Packed(p), _) => packed::execute_packed(tok, p, &self.lut),
            (WaqWeights::Unpacked(_), WaqBackend::Packed) => {
                unreachable!("packed backend always stores packed weights")
            }
        }
    }

    /// Batched decode GEMM: the packed backend runs the cache-tiled,
    /// threaded kernel (weight tiles reused across the batch); the
    /// reference backends fall back to per-token execution.
    pub fn execute_batch(&self, toks: &[QuantToken]) -> Vec<Vec<f32>> {
        match &self.w {
            WaqWeights::Packed(p) => {
                packed::execute_batch_tiled(toks, p, &self.lut, &self.tile)
            }
            WaqWeights::Unpacked(_) => toks.iter().map(|t| self.execute(t)).collect(),
        }
    }

    /// Outlier error compensation over whichever weight form is resident
    /// — the ONE dispatch point for the dual-branch serving forward, so
    /// callers never match on storage themselves.
    pub fn compensate(&self, out: &mut [f32], tok: &QuantToken) {
        match &self.w {
            WaqWeights::Packed(p) => compensation::compensate_packed(out, tok, p),
            WaqWeights::Unpacked(w) => compensation::compensate(out, tok, w),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{self, OutlierCfg};
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    #[test]
    fn backend_parse_and_names() {
        for b in WaqBackend::ALL {
            assert_eq!(WaqBackend::parse(b.name()), Some(b));
            // FromStr/Display round-trip (parse/name are thin aliases)
            assert_eq!(b.to_string().parse::<WaqBackend>(), Ok(b));
            assert_eq!(b.to_string(), b.name());
        }
        assert_eq!(WaqBackend::parse("tpu"), None);
        assert!("tpu".parse::<WaqBackend>().unwrap_err().contains("tpu"));
        assert_eq!(WaqBackend::default(), WaqBackend::Packed);
    }

    #[test]
    fn dispatch_agrees_across_backends() {
        let mut rng = Rng::new(11);
        let (k, n) = (80, 24);
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, 4);
        let calib: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(k, 1.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg::default();
        let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
        let lut = CartesianLut::build(&cb, &qw.codebook);
        let toks: Vec<_> = (0..3)
            .map(|_| quant::quantize_token(&rng.normal_vec(k, 1.0), &cb, cfg))
            .collect();

        let direct = WaqGemm::new(qw.clone(), lut.clone(), WaqBackend::Direct);
        let packed = WaqGemm::new(qw.clone(), lut.clone(), WaqBackend::Packed);
        let hist = WaqGemm::new(qw, lut, WaqBackend::Histogram);

        let want = direct.execute_batch(&toks);
        // packed is bit-exact with direct
        assert_eq!(packed.execute_batch(&toks), want);
        assert_eq!(packed.execute(&toks[0]), want[0]);
        // histogram groups accumulation differently: close, not identical
        let h = hist.execute_batch(&toks);
        for (a, b) in h.iter().zip(&want) {
            crate::util::check::assert_allclose(a, b, 1e-4, 1e-4, "hist vs direct");
        }
    }

    #[test]
    fn two_bit_codebooks_dispatch_to_crumb_density_bit_exact() {
        let mut rng = Rng::new(12);
        let (k, n) = (81, 24); // K % 4 == 1 exercises the crumb tail
        let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
        let qw = quant::quantize_weights(&wmat, 2);
        let calib: Vec<Vec<f32>> =
            (0..4).map(|_| rng.heavy_tailed_vec(k, 0.02, 8.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let cfg = OutlierCfg { total_frac: 0.04 };
        let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
        let lut = CartesianLut::build(&cb, &qw.codebook);
        let toks: Vec<_> = (0..3)
            .map(|_| quant::quantize_token(&rng.heavy_tailed_vec(k, 0.02, 8.0), &cb, cfg))
            .collect();

        let direct = WaqGemm::new(qw.clone(), lut.clone(), WaqBackend::Direct);
        let packed = WaqGemm::new(qw, lut, WaqBackend::Packed);
        // a 2-bit codebook under the packed backend streams four rows per
        // byte through the same unified PackedWeights form
        assert_eq!(packed.packed_weights().map(|p| p.bits()), Some(2));
        assert_eq!(packed.packed_weights().map(|p| p.rows_per_byte()), Some(4));

        // main branch + compensation both bit-exact with the direct path
        let mut want = direct.execute_batch(&toks);
        let mut got = packed.execute_batch(&toks);
        assert_eq!(got, want);
        assert_eq!(packed.execute(&toks[0]), want[0]);
        for ((w, g), t) in want.iter_mut().zip(got.iter_mut()).zip(&toks) {
            direct.compensate(w, t);
            packed.compensate(g, t);
        }
        assert_eq!(got, want);
    }
}
