//! Minimal dense f32 matrix used by the quantization library, the software
//! GEMM paths, and calibration post-processing. Row-major, with a blocked
//! matmul tuned for the single-core testbed (the runtime-critical GEMMs go
//! through PJRT; this type backs algorithm code and references).

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn random_normal(rows: usize, cols: usize, sigma: f32, rng: &mut crate::util::rng::Rng) -> Self {
        Matrix { rows, cols, data: rng.normal_vec(rows * cols, sigma) }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn col(&self, c: usize) -> Vec<f32> {
        (0..self.rows).map(|r| self.at(r, c)).collect()
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Blocked SGEMM: `self (M x K) @ rhs (K x N)`. ikj loop order with a
    /// K-blocking keeps the rhs panel in cache; good enough to serve as the
    /// fair software baseline the WAQ LUT path is compared against.
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, rhs.cols);
        let mut out = Matrix::zeros(m, n);
        const BK: usize = 64;
        for k0 in (0..k).step_by(BK) {
            let k1 = (k0 + BK).min(k);
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let orow = &mut out.data[i * n..(i + 1) * n];
                for kk in k0..k1 {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &rhs.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        orow[j] += a * brow[j];
                    }
                }
            }
        }
        out
    }

    pub fn scale_rows(&mut self, scales: &[f32]) {
        assert_eq!(scales.len(), self.rows);
        for r in 0..self.rows {
            let s = scales[r];
            for v in self.row_mut(r) {
                *v *= s;
            }
        }
    }

    pub fn scale_cols(&mut self, scales: &[f32]) {
        assert_eq!(scales.len(), self.cols);
        for r in 0..self.rows {
            for (c, v) in self.row_mut(r).iter_mut().enumerate() {
                *v *= scales[c];
            }
        }
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }

    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Relative Frobenius error vs a reference.
    pub fn rel_err(&self, reference: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (reference.rows, reference.cols));
        let diff: f64 = self
            .data
            .iter()
            .zip(&reference.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt();
        diff / reference.frob_norm().max(1e-30)
    }

    /// In-place orthonormal fast Walsh-Hadamard transform over columns of
    /// each row (used by the QuaRot baseline); cols must be a power of 2.
    pub fn hadamard_rows(&mut self) {
        let n = self.cols;
        assert!(n.is_power_of_two(), "hadamard dim {n} not power of two");
        let scale = 1.0 / (n as f32).sqrt();
        for r in 0..self.rows {
            let row = &mut self.data[r * n..(r + 1) * n];
            let mut h = 1;
            while h < n {
                let mut i = 0;
                while i < n {
                    for j in i..i + h {
                        let x = row[j];
                        let y = row[j + h];
                        row[j] = x + y;
                        row[j + h] = x - y;
                    }
                    i += 2 * h;
                }
                h *= 2;
            }
            for v in row.iter_mut() {
                *v *= scale;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f32;
                for k in 0..a.cols {
                    acc += a.at(i, k) * b.at(k, j);
                }
                *out.at_mut(i, j) = acc;
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(1, 7, 5), (4, 64, 16), (3, 130, 9)] {
            let a = Matrix::random_normal(m, k, 1.0, &mut rng);
            let b = Matrix::random_normal(k, n, 1.0, &mut rng);
            let got = a.matmul(&b);
            let want = naive_matmul(&a, &b);
            assert!(got.rel_err(&want) < 1e-5, "({m},{k},{n})");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(2);
        let a = Matrix::random_normal(5, 9, 1.0, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn hadamard_preserves_norm_and_inverts() {
        let mut rng = Rng::new(3);
        let a = Matrix::random_normal(4, 64, 1.0, &mut rng);
        let mut h = a.clone();
        h.hadamard_rows();
        assert!((h.frob_norm() - a.frob_norm()).abs() < 1e-4);
        h.hadamard_rows(); // H is an involution (orthonormal, symmetric)
        assert!(h.rel_err(&a) < 1e-5);
    }

    #[test]
    fn hadamard_spreads_outliers() {
        // A single huge channel spreads across all channels after rotation —
        // the mechanism QuaRot relies on.
        let mut a = Matrix::zeros(1, 64);
        *a.at_mut(0, 3) = 64.0;
        let before = a.max_abs();
        a.hadamard_rows();
        assert!(a.max_abs() < before / 4.0);
    }

    #[test]
    fn scale_rows_cols() {
        let mut a = Matrix::from_fn(2, 3, |r, c| (r * 3 + c) as f32);
        a.scale_rows(&[2.0, 3.0]);
        assert_eq!(a.at(1, 2), 15.0);
        a.scale_cols(&[1.0, 0.5, 1.0]);
        assert_eq!(a.at(0, 1), 1.0);
    }
}
