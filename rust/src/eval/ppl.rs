//! Perplexity evaluation + the train-or-load checkpoint helper shared by
//! the accuracy experiments (Table III/IV, Figs 3/5/15/17).

use std::path::PathBuf;

use anyhow::{anyhow, Result};

use super::calibrate::Calibration;
use super::corpora::{Corpus, Generator};
use super::methods::{prepare, Method, Prepared};
use crate::runtime::{HostTensor, ParamSet, Runtime};
use crate::util::rng::Rng;

/// Mean NLL over `n_batches` held-out batches via `loss_eval` (method
/// None) or a quantized-eval artifact.
pub fn eval_nll(
    rt: &mut Runtime,
    artifact: Option<&str>,
    params: &ParamSet,
    extras: &[HostTensor],
    corpus: Corpus,
    n_batches: usize,
    seed: u64,
) -> Result<f64> {
    let m = rt.manifest.model;
    let mut gen = Generator::new(corpus, m.vocab, seed);
    let exe = rt.load(artifact.unwrap_or("loss_eval"))?;
    let mut total = 0.0f64;
    for _ in 0..n_batches {
        let (t, y) = gen.batch(m.batch, m.seq_len);
        let mut inputs = params.tensors.clone();
        inputs.extend(extras.iter().cloned());
        inputs.push(HostTensor::i32(t, &[m.batch, m.seq_len]));
        inputs.push(HostTensor::i32(y, &[m.batch, m.seq_len]));
        let out = exe.run(&inputs)?;
        total += out[0].as_f32()?[0] as f64;
    }
    Ok(total / n_batches as f64)
}

pub fn ppl(nll: f64) -> f64 {
    nll.exp()
}

/// Evaluate one method end-to-end: prepare fake-quant weights + extras,
/// run its artifact, return (ppl, quant_seconds).
pub fn eval_method(
    rt: &mut Runtime,
    fp_params: &ParamSet,
    calib: &Calibration,
    method: Method,
    n_bits: u32,
    corpus: Corpus,
    n_batches: usize,
) -> Result<(f64, f64)> {
    let manifest = rt.manifest.clone();
    let Prepared { params, extras, quant_seconds } =
        prepare(&manifest, fp_params, calib, method, n_bits)?;
    let artifact = method.artifact(n_bits);
    let nll = eval_nll(
        rt,
        artifact.as_deref(),
        &params,
        &extras,
        corpus,
        n_batches,
        0xE7A1,
    )?;
    Ok((ppl(nll), quant_seconds))
}

/// Train a model on `corpus` via the train_step artifact, or load the
/// cached checkpoint if present. Returns (params, loss curve).
pub fn train_or_load(
    rt: &mut Runtime,
    corpus: Corpus,
    steps: usize,
    lr: f32,
    seed: u64,
) -> Result<(ParamSet, Vec<f32>)> {
    let ckpt: PathBuf = rt
        .manifest
        .dir
        .join(format!("ckpt_{}_{}steps.bin", corpus.name(), steps));
    if ckpt.exists() {
        let p = ParamSet::load(&ckpt)?;
        return Ok((p, vec![]));
    }
    let (p, losses) = train(rt, corpus, steps, lr, seed, &mut |_s, _l| {})?;
    p.save(&ckpt)?;
    Ok((p, losses))
}

/// Train loop over the train_step artifact (host-side optimizer state
/// feedback). `progress(step, loss)` is called every step.
pub fn train(
    rt: &mut Runtime,
    corpus: Corpus,
    steps: usize,
    lr: f32,
    seed: u64,
    progress: &mut dyn FnMut(usize, f32),
) -> Result<(ParamSet, Vec<f32>)> {
    let m = rt.manifest.model;
    let manifest = rt.manifest.clone();
    let mut rng = Rng::new(seed);
    let mut params = ParamSet::init(&manifest, &mut rng);
    let mut mstate = ParamSet::zeros_like(&manifest);
    let mut vstate = ParamSet::zeros_like(&manifest);
    let mut gen = Generator::new(corpus, m.vocab, seed ^ 0x7EA1);
    let exe = rt.load("train_step")?;
    let n = params.tensors.len();
    let mut losses = Vec::with_capacity(steps);
    for step in 0..steps {
        let (t, y) = gen.batch(m.batch, m.seq_len);
        let mut inputs = params.tensors.clone();
        inputs.extend(mstate.tensors.iter().cloned());
        inputs.extend(vstate.tensors.iter().cloned());
        inputs.push(HostTensor::scalar_f32((step + 1) as f32));
        inputs.push(HostTensor::scalar_f32(lr));
        inputs.push(HostTensor::i32(t, &[m.batch, m.seq_len]));
        inputs.push(HostTensor::i32(y, &[m.batch, m.seq_len]));
        let out = exe.run(&inputs)?;
        let mut it = out.into_iter();
        params.tensors = (&mut it).take(n).collect();
        mstate.tensors = (&mut it).take(n).collect();
        vstate.tensors = (&mut it).take(n).collect();
        let loss = it
            .next()
            .ok_or_else(|| anyhow!("train_step missing loss output"))?
            .as_f32()?[0];
        losses.push(loss);
        progress(step, loss);
    }
    Ok((params, losses))
}
