//! Per-method weight preparation + artifact selection for the Table III/IV
//! family: given FP parameters and a Calibration, produce the fake-quant
//! parameter set and the extra artifact inputs for each method.

use anyhow::Result;

use super::calibrate::Calibration;
use crate::quant;
use crate::runtime::{HostTensor, Manifest, ParamSet};
use crate::tensor::Matrix;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Fp16,
    Rtn,
    Smooth,
    Quarot,
    Atom,
    /// the paper's method (KLLM/OASIS, dynamic outliers)
    Kmeans,
    /// OASIS-S (static thresholds)
    KmeansStatic,
}

impl Method {
    pub const ALL_QUANT: [Method; 6] = [
        Method::Rtn,
        Method::Smooth,
        Method::Quarot,
        Method::Atom,
        Method::KmeansStatic,
        Method::Kmeans,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Method::Fp16 => "FP16",
            Method::Rtn => "RTN",
            Method::Smooth => "SmoothQuant",
            Method::Quarot => "QuaRot",
            Method::Atom => "Atom",
            Method::Kmeans => "KLLM (OASIS)",
            Method::KmeansStatic => "KLLM-S (OASIS-S)",
        }
    }

    /// artifact name for this method at n_bits (None => plain loss_eval).
    pub fn artifact(&self, n_bits: u32) -> Option<String> {
        let m = match self {
            Method::Fp16 => return None,
            Method::Rtn => "rtn",
            Method::Smooth => "smooth",
            Method::Quarot => "quarot",
            Method::Atom => "atom",
            Method::Kmeans => "kmeans",
            Method::KmeansStatic => "kmeans_static",
        };
        Some(format!("eval_{m}_a{n_bits}"))
    }
}

/// Prepared evaluation inputs: fake-quant weights + method extras.
pub struct Prepared {
    pub params: ParamSet,
    pub extras: Vec<HostTensor>,
    /// wall-clock spent quantizing (Fig 17's quantization-time axis)
    pub quant_seconds: f64,
}

/// Per-linear weight absmax along input channels (for SmoothQuant).
fn weight_absmax(manifest: &Manifest, params: &ParamSet) -> Vec<Vec<f32>> {
    ParamSet::linear_param_names(manifest)
        .iter()
        .map(|name| {
            let idx = ParamSet::index_of(manifest, name).unwrap();
            let w = params.matrix(idx).unwrap();
            (0..w.rows)
                .map(|r| w.row(r).iter().fold(0.0f32, |m, &v| m.max(v.abs())))
                .collect()
        })
        .collect()
}

fn for_each_linear(
    manifest: &Manifest,
    params: &mut ParamSet,
    mut f: impl FnMut(usize, &Matrix) -> Matrix,
) -> Result<()> {
    for (li, name) in ParamSet::linear_param_names(manifest).iter().enumerate() {
        let idx = ParamSet::index_of(manifest, name).unwrap();
        let w = params.matrix(idx)?;
        let new = f(li, &w);
        params.set_matrix(idx, &new)?;
    }
    Ok(())
}

/// Prepare weights + extras for (method, n_bits).
pub fn prepare(
    manifest: &Manifest,
    fp_params: &ParamSet,
    calib: &Calibration,
    method: Method,
    n_bits: u32,
) -> Result<Prepared> {
    let t0 = std::time::Instant::now();
    let mut params = fp_params.clone();
    let extras: Vec<HostTensor> = match method {
        Method::Fp16 => vec![],
        Method::Rtn => {
            for_each_linear(manifest, &mut params, |_, w| {
                quant::rtn::fake_quant_weights(w, n_bits)
            })?;
            vec![]
        }
        Method::Smooth => {
            let wmax = weight_absmax(manifest, fp_params);
            let (sm_d, sm_ff, per_linear) = calib.smooth_vectors(&wmax, 0.5);
            for_each_linear(manifest, &mut params, |li, w| {
                let mut scaled = w.clone();
                scaled.scale_rows(&per_linear[li]);
                quant::rtn::fake_quant_weights(&scaled, n_bits)
            })?;
            vec![sm_d, sm_ff]
        }
        Method::Quarot => {
            for_each_linear(manifest, &mut params, |_, w| {
                quant::quarot::quarot_quantize(w, n_bits)
            })?;
            vec![]
        }
        Method::Atom => {
            let (pd, pf, perms) = calib.atom_perms();
            for_each_linear(manifest, &mut params, |li, w| {
                // quantize in permuted order (so the trailing outlier-channel
                // block matches the artifact's permuted activation view)...
                let mut wp = Matrix::zeros(w.rows, w.cols);
                for (new_r, &old_r) in perms[li].iter().enumerate() {
                    wp.row_mut(new_r).copy_from_slice(w.row(old_r as usize));
                }
                group_quant_inplace(&mut wp, n_bits);
                // ...then restore original row order: the artifact's act_q
                // inverse-permutes activations back before the matmul.
                let mut out = Matrix::zeros(w.rows, w.cols);
                for (new_r, &old_r) in perms[li].iter().enumerate() {
                    out.row_mut(old_r as usize).copy_from_slice(wp.row(new_r));
                }
                out
            })?;
            vec![pd, pf]
        }
        Method::Kmeans => {
            for_each_linear(manifest, &mut params, |_, w| {
                quant::quantize_weights(w, 4).dequantize()
            })?;
            vec![calib.codebooks(n_bits, true)]
        }
        Method::KmeansStatic => {
            for_each_linear(manifest, &mut params, |_, w| {
                quant::quantize_weights(w, 4).dequantize()
            })?;
            vec![calib.codebooks(n_bits, true), calib.thresholds_tensor()]
        }
    };
    Ok(Prepared { params, extras, quant_seconds: t0.elapsed().as_secs_f64() })
}

/// Atom-style group quantization along the input dim: groups of d/32 at
/// n_bits, trailing d/32 outlier block at 8 bits (mirrors model.make_q_atom).
fn group_quant_inplace(w: &mut Matrix, n_bits: u32) {
    let d = w.rows;
    let g = (d / 32).max(1);
    let n_out = g;
    for c in 0..w.cols {
        let mut col: Vec<f32> = (0..d).map(|r| w.at(r, c)).collect();
        let mut r0 = 0;
        while r0 < d {
            let r1 = (r0 + g).min(d);
            let b = if r0 >= d.saturating_sub(n_out) { 8 } else { n_bits };
            let seg = &mut col[r0..r1];
            let m = seg.iter().fold(0.0f32, |mm, &x| mm.max(x.abs()));
            let qmax = ((1i32 << (b - 1)) - 1) as f32;
            quant::rtn::fake_quant_slice(seg, m / qmax, b);
            r0 = r1;
        }
        for r in 0..d {
            *w.at_mut(r, c) = col[r];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifact_names() {
        assert_eq!(Method::Kmeans.artifact(4).as_deref(), Some("eval_kmeans_a4"));
        assert_eq!(Method::Fp16.artifact(4), None);
        assert_eq!(
            Method::KmeansStatic.artifact(3).as_deref(),
            Some("eval_kmeans_static_a3")
        );
    }

    #[test]
    fn all_quant_covers_table3_rows() {
        assert_eq!(Method::ALL_QUANT.len(), 6);
    }
}
