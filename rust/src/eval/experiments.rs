//! Experiment registry: one function per paper table/figure (DESIGN.md §3).
//! Each returns rendered tables; `kllm experiment <id>` prints them and
//! `--md <file>` appends the markdown form (EXPERIMENTS.md capture).

use anyhow::{anyhow, Result};

use super::calibrate::{calibrate, Calibration};
use super::corpora::Corpus;
use super::methods::Method;
use super::ppl::{eval_method, eval_nll, ppl, train_or_load};
use super::tasks::{score_task, Task};
use crate::baselines::{a100_fp16, fig16_costs, figlut, quarot_w4a4};
use crate::gemm::lut::analytics;
use crate::models::{by_name, ZOO};
use crate::quant::OutlierCfg;
use crate::runtime::{artifacts_dir, ParamSet, Runtime};
use crate::sim::{self, HwConfig, OasisMode};
use crate::util::stats;
use crate::util::table::{fmt_ppl, Table};

pub struct ExperimentCtx {
    pub preset: String,
    pub train_steps: usize,
    pub eval_batches: usize,
    pub calib_samples: usize,
}

impl Default for ExperimentCtx {
    fn default() -> Self {
        ExperimentCtx {
            preset: "test".into(),
            train_steps: 250,
            eval_batches: 8,
            calib_samples: 16,
        }
    }
}

impl ExperimentCtx {
    fn runtime(&self) -> Result<Runtime> {
        let dir = artifacts_dir(&self.preset);
        Runtime::new(&dir)
    }

    fn trained(&self, rt: &mut Runtime, corpus: Corpus) -> Result<ParamSet> {
        let (p, _) = train_or_load(rt, corpus, self.train_steps, 3e-3, 0x7121)?;
        Ok(p)
    }

    fn calibration(
        &self,
        rt: &mut Runtime,
        params: &ParamSet,
        corpus: Corpus,
    ) -> Result<Calibration> {
        calibrate(rt, params, corpus, self.calib_samples, OutlierCfg::default())
            .map_err(|e| anyhow!(e))
    }
}

pub fn run(id: &str, ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    match id {
        "table1" => table1(),
        "table2" => table2(),
        "table3" => table3(ctx),
        "table4" => table4(ctx),
        "fig3" => fig3(ctx),
        "fig5" => fig5(ctx),
        "fig11" => fig11(),
        "fig12" => fig12(),
        "fig13" => fig13(),
        "fig14" => fig14(),
        "fig15" => fig15(ctx),
        "fig16" => fig16(),
        "fig17" => fig17(ctx),
        "fig18" => fig18(),
        other => Err(anyhow!(
            "unknown experiment '{other}' (see DESIGN.md §3 for the index)"
        )),
    }
}

pub const ALL_IDS: [&str; 14] = [
    "table1", "table2", "table3", "table4", "fig3", "fig5", "fig11", "fig12",
    "fig13", "fig14", "fig15", "fig16", "fig17", "fig18",
];

// ---------------------------------------------------------------------------
// Table I — LUT scheme configuration comparison
// ---------------------------------------------------------------------------

fn table1() -> Result<Vec<Table>> {
    let (k, n) = (4096usize, 4096usize);
    let mut t = Table::new(
        "Table I — LUT-based GEMM schemes (M=1, K=N=4096, nW=nA=4, mu=4)",
        &["Scheme", "Act prec", "Offline LUT?", "Group size", "LUT entries", "Reduction FLOPs"],
    );
    t.row(&[
        "WOQ LUT-GEMM".to_string(),
        "FP16".into(),
        "no".into(),
        "4".into(),
        analytics::woq_lut_entries(k, 4).to_string(),
        analytics::woq_reduction_flops(k, 4, 4, n).to_string(),
    ]);
    t.row(&[
        "WAQ LUT-GEMM (ours)".to_string(),
        "NU4".into(),
        "yes".into(),
        k.to_string(),
        analytics::waq_lut_entries(4, 4).to_string(),
        analytics::waq_reduction_flops(4, 4, n).to_string(),
    ]);
    t.note(&format!(
        "LUT-size reduction {}x, FLOP reduction {}x (paper claims 64x / 16x)",
        analytics::woq_lut_entries(k, 4) / analytics::waq_lut_entries(4, 4),
        analytics::woq_reduction_flops(k, 4, 4, n) / analytics::waq_reduction_flops(4, 4, n)
    ));
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Table II — accelerator configuration
// ---------------------------------------------------------------------------

fn table2() -> Result<Vec<Table>> {
    let hw = HwConfig::default();
    let (a, p) = (&hw.area_mm2, &hw.power_w);
    let mut t = Table::new(
        "Table II — OASIS accelerator configuration (28nm, 500MHz)",
        &["Module", "Spec", "Area (mm2)", "Power (W)"],
    );
    let rows: Vec<(String, String, f64, f64)> = vec![
        ("PE Lines".into(), format!("{} per chip", hw.pe_lines), a.pe_lines_total, p.pe_lines_total),
        ("  Concat Unit".into(), format!("{} per line", hw.concat_units_per_line), a.concat_unit, p.concat_unit),
        ("  Wgt Idx Buffer".into(), format!("{} KB per line", hw.wgt_idx_buffer_bytes / 1024), a.wgt_idx_buffer, p.wgt_idx_buffer),
        ("  Index Counter".into(), format!("{} {}-in per line", hw.index_counters_per_line, hw.index_counter_inputs), a.index_counter, p.index_counter),
        ("  Dequant Unit".into(), "1 per line".into(), a.dequant_unit, p.dequant_unit),
        ("  MAC Tree".into(), format!("1 {}-in per line", hw.mac_tree_inputs), a.mac_tree, p.mac_tree),
        ("  MAC".into(), format!("{} per line", hw.macs_per_line), a.mac, p.mac),
        ("Output Buffer".into(), format!("{} KB", hw.output_buffer_bytes / 1024), a.output_buffer, p.output_buffer),
        ("Act Idx Buffer".into(), format!("{} KB", hw.act_idx_buffer_bytes / 1024), a.act_idx_buffer, p.act_idx_buffer),
        ("LUT".into(), format!("{} KB", hw.lut_bytes / 1024), a.lut, p.lut),
        ("Clustering Unit".into(), format!("{} per chip", hw.clustering_units), a.clustering_unit, p.clustering_unit),
        ("Orizuru".into(), format!("{} {}-in units", hw.orizuru_units, hw.orizuru_inputs), a.orizuru, p.orizuru),
        ("Error Calc Unit".into(), "1 per chip".into(), a.error_calc_unit, p.error_calc_unit),
        ("Func Unit".into(), "1 per chip".into(), a.func_unit, p.func_unit),
        ("Memory Controller".into(), "1 per chip".into(), a.memory_controller, p.memory_controller),
    ];
    for (m, s, ar, pw) in rows {
        t.row(&[m, s, format!("{ar:.3}"), format!("{pw:.3}")]);
    }
    t.sep();
    t.row(&[
        "Total".to_string(),
        "-".into(),
        format!("{:.2}", hw.total_area_mm2()),
        format!("{:.2}", hw.total_power_w()),
    ]);
    t.note("paper totals: 15.31 mm2 / 9.66 W");
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Table III — perplexity across methods
// ---------------------------------------------------------------------------

fn table3(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let mut rt = ctx.runtime()?;
    let params = ctx.trained(&mut rt, Corpus::Wiki2)?;
    let calib = ctx.calibration(&mut rt, &params, Corpus::C4)?;

    let fp_nll = eval_nll(&mut rt, None, &params, &[], Corpus::Wiki2, ctx.eval_batches, 0xE7A1)?;
    let mut t = Table::new(
        &format!(
            "Table III — synthetic-WikiText2 PPL ({} preset, {} train steps)",
            ctx.preset, ctx.train_steps
        ),
        &["Precision", "Method", "PPL", "dPPL vs FP16"],
    );
    t.row(&["FP16".to_string(), "-".into(), fmt_ppl(ppl(fp_nll)), "-".into()]);
    for &bits in &[4u32, 3u32] {
        t.sep();
        for method in Method::ALL_QUANT {
            let (p, _) = eval_method(
                &mut rt, &params, &calib, method, bits, Corpus::Wiki2, ctx.eval_batches,
            )?;
            t.row(&[
                format!("W4A{bits}"),
                method.label().to_string(),
                fmt_ppl(p),
                format!("{:+.2}", p - ppl(fp_nll)),
            ]);
        }
    }
    t.note("models substituted per DESIGN.md §1.3; ordering is the claim, not absolute PPL");
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Table IV — zero-shot-style tasks
// ---------------------------------------------------------------------------

fn table4(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let mut rt = ctx.runtime()?;
    let params = ctx.trained(&mut rt, Corpus::Wiki2)?;
    let calib = ctx.calibration(&mut rt, &params, Corpus::C4)?;
    let n_examples = 24;

    let mut t = Table::new(
        "Table IV — zero-shot-style accuracy (binary likelihood tasks)",
        &["Precision", "Method", "Contin.", "Chain-E", "Chain-C", "Recall", "LongCont", "FreqPrior", "Avg"],
    );
    let methods: Vec<(String, Method, u32)> = vec![
        ("FP16".into(), Method::Fp16, 4),
        ("W4A4".into(), Method::Quarot, 4),
        ("W4A4".into(), Method::Atom, 4),
        ("W4A4".into(), Method::KmeansStatic, 4),
        ("W4A4".into(), Method::Kmeans, 4),
        ("W4A3".into(), Method::Kmeans, 3),
    ];
    for (prec, method, bits) in methods {
        let manifest = rt.manifest.clone();
        let prep = super::methods::prepare(&manifest, &params, &calib, method, bits)?;
        let artifact = method.artifact(bits);
        let mut row = vec![prec, method.label().to_string()];
        let mut accs = Vec::new();
        for task in Task::ALL {
            let acc = score_task(
                &mut rt,
                artifact.as_deref(),
                &prep.params,
                &prep.extras,
                task,
                n_examples,
            )?;
            accs.push(acc);
            row.push(format!("{:.1}", acc * 100.0));
        }
        row.push(format!(
            "{:.1}",
            accs.iter().sum::<f64>() / accs.len() as f64 * 100.0
        ));
        t.row(&row);
    }
    t.note("tasks are synthetic binary-choice suites (DESIGN.md §1.3)");
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Fig 3 / Fig 5 — online vs offline thresholds / centroids
// ---------------------------------------------------------------------------

fn fig3(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let mut rt = ctx.runtime()?;
    let params = ctx.trained(&mut rt, Corpus::Wiki2)?;
    let mut t = Table::new(
        "Fig 3 — online vs offline upper outlier thresholds (normalized RMSE)",
        &["Online", "Offline (calib)", "RMSE(thresholds)", "RMSE(centroids, Fig5)"],
    );
    for offline in [Corpus::C4, Corpus::Ptb] {
        let on = ctx.calibration(&mut rt, &params, Corpus::Wiki2)?;
        let off = ctx.calibration(&mut rt, &params, offline)?;
        // per-linear upper thresholds, normalized to [0,1] jointly
        let on_hi: Vec<f32> = on.thresholds.iter().map(|&(_, h)| h).collect();
        let off_hi: Vec<f32> = off.thresholds.iter().map(|&(_, h)| h).collect();
        let rmse_t = stats::rmse(&stats::normalize01(&on_hi), &stats::normalize01(&off_hi));
        // centroid consistency (Fig 5): layer-0 qkv input codebooks
        let cb_on = on.learn_codebook(0, 4, false);
        let cb_off = off.learn_codebook(0, 4, false);
        let rmse_c = stats::rmse(
            &stats::normalize01(&cb_on.centroids),
            &stats::normalize01(&cb_off.centroids),
        );
        t.row(&[
            "wiki2-syn".to_string(),
            offline.name().to_string(),
            format!("{rmse_t:.3}"),
            format!("{rmse_c:.3}"),
        ]);
    }
    t.note("paper: threshold RMSE 0.32/0.38 (large), centroid RMSE 0.01 (small)");
    Ok(vec![t])
}

fn fig5(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let mut rt = ctx.runtime()?;
    let params = ctx.trained(&mut rt, Corpus::Wiki2)?;
    let mut t = Table::new(
        "Fig 5 — online vs offline 4-bit activation centroids (normalized RMSE per linear)",
        &["Offline calib", "mean RMSE", "max RMSE"],
    );
    for offline in [Corpus::C4, Corpus::Ptb] {
        let on = ctx.calibration(&mut rt, &params, Corpus::Wiki2)?;
        let off = ctx.calibration(&mut rt, &params, offline)?;
        let mut rmses = Vec::new();
        for li in 0..on.acts.len() {
            let a = on.learn_codebook(li, 4, false);
            let b = off.learn_codebook(li, 4, false);
            rmses.push(stats::rmse(
                &stats::normalize01(&a.centroids),
                &stats::normalize01(&b.centroids),
            ) as f32);
        }
        t.row(&[
            offline.name().to_string(),
            format!("{:.4}", stats::mean(&rmses)),
            format!("{:.4}", rmses.iter().fold(0.0f32, |m, &x| m.max(x))),
        ]);
    }
    t.note("paper: centroid RMSE ~0.01 — offline centroids transfer");
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Fig 11/12/13 — simulated throughput/energy vs baselines
// ---------------------------------------------------------------------------

fn fig11() -> Result<Vec<Table>> {
    let hw = HwConfig::default();
    let out_len = 2048;
    let mut t = Table::new(
        "Fig 11 — single-batch decode, normalized to FIGLUT (out len 2048)",
        &["Model", "A100", "QuaRot", "FIGLUT", "OASIS-A4", "OASIS-A3", "E(A100)", "E(QuaRot)", "E(FIGLUT)", "E(A4)", "E(A3)"],
    );
    let mut sp_a100 = Vec::new();
    let mut sp_quarot = Vec::new();
    let mut sp_figlut = Vec::new();
    let mut ee_figlut = Vec::new();
    for m in ZOO {
        let f = figlut().generation_cost(m, 1, 0, out_len);
        let a4 = sim::generation_cost(&hw, m, OasisMode::a4(), 1, 0, out_len);
        let a3 = sim::generation_cost(&hw, m, OasisMode::a3(), 1, 0, out_len);
        let gpu = a100_fp16();
        let qr = quarot_w4a4();
        let tp = |s: f64| out_len as f64 / s;
        let base_tp = tp(f.seconds);
        let base_e = f.energy_j;
        let a100_cell = if gpu.fits(m) {
            let g = gpu.generation_cost(m, 1, 0, out_len);
            sp_a100.push(tp(a4.seconds) / tp(g.seconds));
            format!("{:.2}", tp(g.seconds) / base_tp)
        } else {
            "OOM".into()
        };
        let qr_cost = qr.generation_cost(m, 1, 0, out_len);
        sp_quarot.push(tp(a4.seconds) / tp(qr_cost.seconds));
        sp_figlut.push(tp(a4.seconds) / base_tp);
        ee_figlut.push(base_e / a4.energy_j);
        t.row(&[
            m.name.to_string(),
            a100_cell,
            format!("{:.2}", tp(qr_cost.seconds) / base_tp),
            "1.00".into(),
            format!("{:.2}", tp(a4.seconds) / base_tp),
            format!("{:.2}", tp(a3.seconds) / base_tp),
            if gpu.fits(m) {
                format!("{:.0}", gpu.generation_cost(m, 1, 0, out_len).energy_j / base_e)
            } else {
                "OOM".into()
            },
            format!("{:.0}", qr_cost.energy_j / base_e),
            "1.0".into(),
            format!("{:.2}", a4.energy_j / base_e),
            format!("{:.2}", a3.energy_j / base_e),
        ]);
    }
    t.note(&format!(
        "avg OASIS-A4 speedup: {:.2}x vs A100, {:.2}x vs QuaRot, {:.2}x vs FIGLUT (paper: 5.41/3.12/3.00); avg energy-eff vs FIGLUT {:.2}x (paper 1.44x)",
        stats::geomean(&sp_a100),
        stats::geomean(&sp_quarot),
        stats::geomean(&sp_figlut),
        stats::geomean(&ee_figlut),
    ));
    Ok(vec![t])
}

fn fig12() -> Result<Vec<Table>> {
    let hw = HwConfig::default();
    let out_len = 512;
    let mut t = Table::new(
        "Fig 12 — low-batch decoding throughput (tokens/s), LLaMA-2-7B/13B",
        &["Model", "Batch", "A100", "QuaRot", "FIGLUT", "OASIS-A4", "OASIS-A3"],
    );
    for name in ["LLaMA-2-7B", "LLaMA-2-13B"] {
        let m = by_name(name).unwrap();
        for batch in [1usize, 2, 4] {
            let tp = |s: f64| (out_len * batch) as f64 / s;
            t.row(&[
                name.to_string(),
                batch.to_string(),
                format!("{:.1}", a100_fp16().decode_throughput(m, batch, out_len)),
                format!("{:.1}", quarot_w4a4().decode_throughput(m, batch, out_len)),
                format!("{:.1}", figlut().decode_throughput(m, batch, out_len)),
                format!("{:.1}", tp(sim::generation_cost(&hw, m, OasisMode::a4(), batch, 0, out_len).seconds)),
                format!("{:.1}", tp(sim::generation_cost(&hw, m, OasisMode::a3(), batch, 0, out_len).seconds)),
            ]);
        }
        t.sep();
    }
    t.note("paper: avg 3.41x/3.73x speedup over baselines for A4/A3");
    Ok(vec![t])
}

fn fig13() -> Result<Vec<Table>> {
    let hw = HwConfig::default();
    let mut t = Table::new(
        "Fig 13 — prefill/decode pairs vs FIGLUT (speedup of OASIS-A4/A3)",
        &["Model", "prefill", "decode", "FIGLUT tok/s", "OASIS-A4 x", "OASIS-A3 x"],
    );
    let mut ratios4 = Vec::new();
    for name in ["LLaMA-2-7B", "LLaMA-2-70B"] {
        let m = by_name(name).unwrap();
        for (p, d) in [(128usize, 128usize), (128, 512), (512, 128), (1024, 512)] {
            let f = figlut().generation_cost(m, 1, p, d);
            let a4 = sim::generation_cost(&hw, m, OasisMode::a4(), 1, p, d);
            let a3 = sim::generation_cost(&hw, m, OasisMode::a3(), 1, p, d);
            ratios4.push(f.seconds / a4.seconds);
            t.row(&[
                name.to_string(),
                p.to_string(),
                d.to_string(),
                format!("{:.1}", d as f64 / f.seconds),
                format!("{:.2}", f.seconds / a4.seconds),
                format!("{:.2}", f.seconds / a3.seconds),
            ]);
        }
        t.sep();
    }
    t.note(&format!(
        "avg OASIS-A4 speedup over FIGLUT: {:.2}x (paper 2.80x)",
        stats::geomean(&ratios4)
    ));
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Fig 14 — pipeline schedule
// ---------------------------------------------------------------------------

fn fig14() -> Result<Vec<Table>> {
    let hw = HwConfig::default();
    let s = sim::pipeline::schedule(&hw, 1, 4096, 4096, 4, 0.01);
    let mut t = Table::new(
        "Fig 14 — pipeline of a 1-4096-4096 W4A4 GEMM, 1% outliers (cycles)",
        &["Branch", "Step", "Start", "Cycles", "Bottleneck"],
    );
    for st in &s.steps {
        t.row(&[
            st.branch.to_string(),
            st.name.to_string(),
            st.start.to_string(),
            st.cycles.to_string(),
            if st.bottleneck { "**" } else { "" }.to_string(),
        ]);
    }
    t.note(&format!(
        "main ends {} / outlier ends {} / total {} cycles; outlier branch {:.0}% faster (paper ~33%)",
        s.main_end,
        s.outlier_end,
        s.total,
        (1.0 - s.outlier_end as f64 / s.main_end as f64) * 100.0
    ));
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Fig 15 — outlier-percentage sensitivity (PPL + throughput + OASIS-C)
// ---------------------------------------------------------------------------

fn fig15(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let hw = HwConfig::default();
    let mut rt = ctx.runtime()?;
    let params = ctx.trained(&mut rt, Corpus::Wiki2)?;
    let calib = ctx.calibration(&mut rt, &params, Corpus::C4)?;
    let manifest = rt.manifest.clone();
    let prep = super::methods::prepare(&manifest, &params, &calib, Method::Kmeans, 4)?;

    let mut t = Table::new(
        "Fig 15 — outlier % sweep: PPL and normalized throughput (LLaMA-2-7B model shapes)",
        &["Outlier %", "PPL (ours)", "Thr A4 (norm)", "Thr A3 (norm)"],
    );
    let m7b = by_name("LLaMA-2-7B").unwrap();
    let base4 = sim::generation_cost(&hw, m7b, OasisMode::a4(), 1, 0, 256).seconds;
    let base3 = sim::generation_cost(&hw, m7b, OasisMode::a3(), 1, 0, 256).seconds;
    for (frac, artifact) in [
        (0.005, "eval_kmeans_a4_f005"),
        (0.01, "eval_kmeans_a4"),
        (0.02, "eval_kmeans_a4_f02"),
        (0.05, "eval_kmeans_a4_f05"),
        (0.10, "eval_kmeans_a4_f1"),
    ] {
        let ppl_cell = if rt.manifest.artifacts.contains_key(artifact) {
            let nll = eval_nll(
                &mut rt, Some(artifact), &prep.params, &prep.extras,
                Corpus::Wiki2, ctx.eval_batches, 0xE7A1,
            )?;
            fmt_ppl(ppl(nll))
        } else {
            "n/a".into()
        };
        let mode4 = OasisMode { outlier_frac: frac, ..OasisMode::a4() };
        let mode3 = OasisMode { outlier_frac: frac, ..OasisMode::a3() };
        let s4 = sim::generation_cost(&hw, m7b, mode4, 1, 0, 256).seconds;
        let s3 = sim::generation_cost(&hw, m7b, mode3, 1, 0, 256).seconds;
        t.row(&[
            format!("{:.1}%", frac * 100.0),
            ppl_cell,
            format!("{:.2}", base4 / s4),
            format!("{:.2}", base3 / s3),
        ]);
    }
    // OASIS-C comparison (§V-D4)
    let la = sim::generation_cost(&hw, m7b, OasisMode::a4(), 1, 0, 256).seconds;
    let cp = sim::generation_cost(
        &hw, m7b, OasisMode { lookahead: false, ..OasisMode::a4() }, 1, 0, 256,
    )
    .seconds;
    t.note(&format!(
        "look-ahead vs critical-path (OASIS-C): +{:.0}% throughput at 1% outliers (paper +16%)",
        (cp / la - 1.0) * 100.0
    ));
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Fig 16 — LUT sizes / reduction FLOPs vs WOQ designs
// ---------------------------------------------------------------------------

fn fig16() -> Result<Vec<Table>> {
    let mut t = Table::new(
        "Fig 16 — q_proj LUT entries and reduction FLOPs (W4A16 baselines vs OASIS-A4)",
        &["Model", "Design", "LUT entries", "Reduction FLOPs"],
    );
    let mut lut_ratios = Vec::new();
    let mut flop_ratios = Vec::new();
    for name in ["LLaMA-7B", "LLaMA-13B", "LLaMA-30B", "LLaMA-2-70B"] {
        let m = by_name(name).unwrap();
        let d = m.d_model;
        let costs = fig16_costs(d, d);
        let oasis = costs.iter().find(|c| c.name == "OASIS-A4").unwrap();
        let fig = costs.iter().find(|c| c.name == "FIGLUT").unwrap();
        lut_ratios.push(fig.lut_entries as f64 / oasis.lut_entries as f64);
        flop_ratios.push(fig.reduction_flops as f64 / oasis.reduction_flops as f64);
        for c in &costs {
            t.row(&[
                name.to_string(),
                c.name.to_string(),
                c.lut_entries.to_string(),
                c.reduction_flops.to_string(),
            ]);
        }
        t.sep();
    }
    t.note(&format!(
        "avg vs FIGLUT: LUT {:.1}x smaller, reduction FLOPs {:.1}x fewer (paper: 62.1x / 497.1x incl. per-token regeneration)",
        stats::geomean(&lut_ratios),
        stats::geomean(&flop_ratios)
    ));
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Fig 17 — calibration dataset / sample count sensitivity
// ---------------------------------------------------------------------------

fn fig17(ctx: &ExperimentCtx) -> Result<Vec<Table>> {
    let mut rt = ctx.runtime()?;
    let params = ctx.trained(&mut rt, Corpus::Wiki2)?;
    let mut t = Table::new(
        "Fig 17 — calibration dataset & sample count vs PPL and quant time",
        &["Calib set", "Samples", "PPL", "Quant time (s)"],
    );
    for corpus in [Corpus::C4, Corpus::Ptb] {
        for n in [4usize, 8, 16, 32] {
            let t0 = std::time::Instant::now();
            let calib = calibrate(&mut rt, &params, corpus, n, OutlierCfg::default())
                .map_err(|e| anyhow!(e))?;
            let manifest = rt.manifest.clone();
            let prep =
                super::methods::prepare(&manifest, &params, &calib, Method::Kmeans, 4)?;
            let quant_s = t0.elapsed().as_secs_f64();
            let nll = eval_nll(
                &mut rt, Some("eval_kmeans_a4"), &prep.params, &prep.extras,
                Corpus::Wiki2, ctx.eval_batches, 0xE7A1,
            )?;
            t.row(&[
                corpus.name().to_string(),
                n.to_string(),
                fmt_ppl(ppl(nll)),
                format!("{quant_s:.2}"),
            ]);
        }
        t.sep();
    }
    t.note("paper: PPL converges ~16 samples; time grows superlinearly beyond");
    Ok(vec![t])
}

// ---------------------------------------------------------------------------
// Fig 18 — memory-traffic + energy breakdown
// ---------------------------------------------------------------------------

fn fig18() -> Result<Vec<Table>> {
    let hw = HwConfig::default();
    let c = sim::gemm_cost(&hw, 1, 4096, 4096, 4, 0.01);
    let traffic = sim::energy::gemm_traffic(&hw, &c, 4);
    let energy = sim::energy::gemm_energy(&hw, &c, 4);
    let mut t1 = Table::new(
        "Fig 18(a) — on-chip memory traffic, 1-4096-4096 GEMM, 1% outliers",
        &["Component", "Bytes", "Share"],
    );
    for (k, v) in &traffic.by_component {
        t1.row(&[
            k.to_string(),
            format!("{:.0}", v),
            format!("{:.1}%", traffic.fraction(k) * 100.0),
        ]);
    }
    t1.note("paper: Weight Index Buffer 76.0%, LUT 19.2%");
    let mut t2 = Table::new(
        "Fig 18(b) — on-chip energy breakdown",
        &["Component", "uJ", "Share"],
    );
    for (k, v) in &energy.by_component {
        t2.row(&[
            k.to_string(),
            format!("{:.2}", v * 1e6),
            format!("{:.1}%", energy.fraction(k) * 100.0),
        ]);
    }
    t2.note("paper: reduction 33.1%, merge 22.1%");
    Ok(vec![t1, t2])
}
