//! Calibration: run `collect_acts` on a calibration corpus and distill
//! everything every quantization method needs — per-linear activation
//! codebooks (Fisher-weighted K-Means, §V-A), static outlier thresholds
//! (OASIS-S), per-channel absmax (SmoothQuant / Atom), and channel
//! permutations (Atom).

use anyhow::Result;

use super::corpora::{Corpus, Generator};
use crate::quant::{self, Codebook, OutlierCfg};
use crate::runtime::{HostTensor, ParamSet, Runtime};

/// Everything distilled from calibration activations.
pub struct Calibration {
    /// per-linear normalized activation codebooks, one per n_bits choice
    /// is learned on demand via `codebooks(bits)` — raw samples kept here
    pub acts: Vec<Vec<f32>>,   // [n_linears][samples]
    pub fisher: Vec<Vec<f32>>, // [n_linears][samples] squared grads
    /// per-linear (lo, hi) static thresholds
    pub thresholds: Vec<(f32, f32)>,
    /// per-linear per-channel absmax (for smooth/atom); channel dim varies
    pub absmax: Vec<Vec<f32>>,
    pub n_layers: usize,
    pub d_model: usize,
    pub d_ff: usize,
    /// token dimension of each linear input
    pub dims: Vec<usize>,
    /// tokens used
    pub n_tokens: usize,
    pub outlier: OutlierCfg,
}

/// Which linear a flat index maps to (kind 0..2 are d-dim, 3 is ff-dim),
/// matching python model.LINEARS_PER_LAYER ordering.
fn linear_dims(n_layers: usize, d: usize, dff: usize) -> Vec<usize> {
    let mut v = Vec::with_capacity(4 * n_layers);
    for _ in 0..n_layers {
        v.extend_from_slice(&[d, d, d, dff]);
    }
    v
}

/// Run collect_acts over `n_samples` batches of the calibration corpus.
pub fn calibrate(
    rt: &mut Runtime,
    params: &ParamSet,
    corpus: Corpus,
    n_samples: usize,
    outlier: OutlierCfg,
) -> Result<Calibration> {
    let m = rt.manifest.model;
    let (nl, d, dff) = (m.n_layers, m.d_model, m.d_ff);
    let n_linears = 4 * nl;
    let dims = linear_dims(nl, d, dff);

    let mut acts: Vec<Vec<f32>> = vec![Vec::new(); n_linears];
    let mut fisher: Vec<Vec<f32>> = vec![Vec::new(); n_linears];
    let mut absmax: Vec<Vec<f32>> = dims.iter().map(|&dd| vec![0.0f32; dd]).collect();
    let mut per_token_thresholds: Vec<(Vec<f32>, Vec<f32>)> =
        vec![(Vec::new(), Vec::new()); n_linears];

    let mut gen = Generator::new(corpus, m.vocab, 0xCA11B);
    let exe = rt.load("collect_acts")?;
    let tokens_per_batch = m.batch * m.seq_len;
    let n_batches = n_samples.div_ceil(m.batch).max(1);

    for _ in 0..n_batches {
        let (t, y) = gen.batch(m.batch, m.seq_len);
        let mut inputs = params.tensors.clone();
        inputs.push(HostTensor::i32(t, &[m.batch, m.seq_len]));
        inputs.push(HostTensor::i32(y, &[m.batch, m.seq_len]));
        let out = exe.run(&inputs)?;
        // outputs: acts_d (3L,B,T,d), acts_ff (L,B,T,ff), gd, gf
        let (ad, af, gd, gf) = (
            out[0].as_f32()?,
            out[1].as_f32()?,
            out[2].as_f32()?,
            out[3].as_f32()?,
        );
        for li in 0..n_linears {
            let (l, kind) = (li / 4, li % 4);
            let (src, gsrc, dd) = if kind == 3 {
                (
                    &af[l * tokens_per_batch * dff..(l + 1) * tokens_per_batch * dff],
                    &gf[l * tokens_per_batch * dff..(l + 1) * tokens_per_batch * dff],
                    dff,
                )
            } else {
                let s = (3 * l + kind) * tokens_per_batch * d;
                (&ad[s..s + tokens_per_batch * d], &gd[s..s + tokens_per_batch * d], d)
            };
            for tok in 0..tokens_per_batch {
                let row = &src[tok * dd..(tok + 1) * dd];
                let grow = &gsrc[tok * dd..(tok + 1) * dd];
                // per-token thresholds (k-th largest/smallest)
                let k = outlier.k_per_side(dd);
                let mut sorted: Vec<f32> = row.to_vec();
                sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
                per_token_thresholds[li].0.push(sorted[k - 1]);
                per_token_thresholds[li].1.push(sorted[dd - k]);
                for (c, (&v, &_g)) in row.iter().zip(grow).enumerate() {
                    absmax[li][c] = absmax[li][c].max(v.abs());
                }
                // subsample activations for codebook learning
                let stride = (dd / 64).max(1);
                let mut c = tok % stride;
                while c < dd {
                    acts[li].push(row[c]);
                    fisher[li].push(grow[c] * grow[c]);
                    c += stride;
                }
            }
        }
    }

    let thresholds = per_token_thresholds
        .iter()
        .map(|(lo, hi)| {
            (
                lo.iter().sum::<f32>() / lo.len().max(1) as f32,
                hi.iter().sum::<f32>() / hi.len().max(1) as f32,
            )
        })
        .collect();

    Ok(Calibration {
        acts,
        fisher,
        thresholds,
        absmax,
        n_layers: nl,
        d_model: d,
        d_ff: dff,
        dims,
        n_tokens: n_batches * tokens_per_batch,
        outlier,
    })
}

impl Calibration {
    /// Learn the per-linear normalized activation codebooks at `bits`
    /// (Fisher-weighted when `weighted`). Returns the (n_linears, 2^bits)
    /// tensor the `eval_kmeans_*` artifacts expect.
    pub fn codebooks(&self, bits: u32, weighted: bool) -> HostTensor {
        let n_linears = self.acts.len();
        let mut data = Vec::with_capacity(n_linears << bits);
        for li in 0..n_linears {
            let cb = self.learn_codebook(li, bits, weighted);
            data.extend_from_slice(&cb.centroids);
        }
        HostTensor::f32(data, &[n_linears, 1usize << bits])
    }

    pub fn learn_codebook(&self, li: usize, bits: u32, weighted: bool) -> Codebook {
        // normalize samples per-linear by the 99.5th-percentile magnitude
        // (a robust stand-in for the per-token inlier scale)
        let xs = &self.acts[li];
        let mut mags: Vec<f32> = xs.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let scale = mags[((mags.len() - 1) as f64 * 0.995) as usize].max(1e-9);
        let normed: Vec<f32> = xs.iter().map(|x| (x / scale).clamp(-1.0, 1.0)).collect();
        let w = if weighted { Some(self.fisher[li].as_slice()) } else { None };
        Codebook::new(quant::kmeans::weighted_kmeans_1d(&normed, w, 1 << bits, 30))
    }

    /// (n_linears, 2) static thresholds tensor for `eval_kmeans_static_*`.
    pub fn thresholds_tensor(&self) -> HostTensor {
        let mut data = Vec::with_capacity(self.thresholds.len() * 2);
        for &(lo, hi) in &self.thresholds {
            data.push(lo);
            data.push(hi);
        }
        HostTensor::f32(data, &[self.thresholds.len(), 2])
    }

    /// SmoothQuant vectors: (3L, d) and (L, ff) smoothing tensors plus the
    /// per-linear vectors for weight-side scaling.
    pub fn smooth_vectors(&self, params_absmax_w: &[Vec<f32>], alpha: f64) -> (HostTensor, HostTensor, Vec<Vec<f32>>) {
        let (nl, d, dff) = (self.n_layers, self.d_model, self.d_ff);
        let mut sm_d = vec![0.0f32; 3 * nl * d];
        let mut sm_ff = vec![0.0f32; nl * dff];
        let mut per_linear = Vec::with_capacity(4 * nl);
        for li in 0..4 * nl {
            let (l, kind) = (li / 4, li % 4);
            let a = &self.absmax[li];
            let w = &params_absmax_w[li];
            let s: Vec<f32> = a
                .iter()
                .zip(w)
                .map(|(&am, &wm)| {
                    ((am.max(1e-6) as f64).powf(alpha)
                        / (wm.max(1e-6) as f64).powf(1.0 - alpha))
                    .max(1e-6) as f32
                })
                .collect();
            if kind == 3 {
                sm_ff[l * dff..(l + 1) * dff].copy_from_slice(&s);
            } else {
                let off = (3 * l + kind) * d;
                sm_d[off..off + d].copy_from_slice(&s);
            }
            per_linear.push(s);
        }
        (
            HostTensor::f32(sm_d, &[3 * nl, d]),
            HostTensor::f32(sm_ff, &[nl, dff]),
            per_linear,
        )
    }

    /// Atom permutations: (3L, d) and (L, ff) i32 tensors + per-linear perms.
    pub fn atom_perms(&self) -> (HostTensor, HostTensor, Vec<Vec<u32>>) {
        let (nl, d, dff) = (self.n_layers, self.d_model, self.d_ff);
        let mut pd = vec![0i32; 3 * nl * d];
        let mut pf = vec![0i32; nl * dff];
        let mut per_linear = Vec::with_capacity(4 * nl);
        for li in 0..4 * nl {
            let (l, kind) = (li / 4, li % 4);
            let perm = quant::atom::outlier_permutation(&self.absmax[li]);
            if kind == 3 {
                for (i, &p) in perm.iter().enumerate() {
                    pf[l * dff + i] = p as i32;
                }
            } else {
                let off = (3 * l + kind) * d;
                for (i, &p) in perm.iter().enumerate() {
                    pd[off + i] = p as i32;
                }
            }
            per_linear.push(perm);
        }
        (
            HostTensor::i32(pd, &[3 * nl, d]),
            HostTensor::i32(pf, &[nl, dff]),
            per_linear,
        )
    }
}
