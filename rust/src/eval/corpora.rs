//! Synthetic corpora standing in for WikiText-2 / C4 / PTB (DESIGN.md
//! §1.3): order-1 Markov chains with Zipfian marginals, parameterized per
//! corpus so that (a) a small transformer can learn them (PPL drops well
//! below the uniform baseline) and (b) the corpora *differ* from each
//! other — the distribution shift Figs 3/5/17 measure.

use crate::util::rng::{Rng, ZipfTable};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Corpus {
    /// WikiText-2 stand-in: the training/eval corpus
    Wiki2,
    /// C4 stand-in: large, diverse calibration corpus
    C4,
    /// PTB stand-in: smaller, more skewed calibration corpus
    Ptb,
}

impl Corpus {
    pub fn name(&self) -> &'static str {
        match self {
            Corpus::Wiki2 => "wiki2-syn",
            Corpus::C4 => "c4-syn",
            Corpus::Ptb => "ptb-syn",
        }
    }

    pub fn parse(s: &str) -> Option<Corpus> {
        match s {
            "wiki2" | "wiki2-syn" => Some(Corpus::Wiki2),
            "c4" | "c4-syn" => Some(Corpus::C4),
            "ptb" | "ptb-syn" => Some(Corpus::Ptb),
            _ => None,
        }
    }

    fn seed(&self) -> u64 {
        match self {
            Corpus::Wiki2 => 0x11AA,
            Corpus::C4 => 0x22BB,
            Corpus::Ptb => 0x33CC,
        }
    }

    fn zipf_exponent(&self) -> f64 {
        match self {
            Corpus::Wiki2 => 1.05,
            Corpus::C4 => 0.95,
            Corpus::Ptb => 1.25,
        }
    }

    /// branching factor of the Markov chain (successors per token)
    fn branching(&self) -> usize {
        match self {
            Corpus::Wiki2 => 12,
            Corpus::C4 => 24,
            Corpus::Ptb => 6,
        }
    }
}

/// Deterministic order-1 Markov generator over `vocab` tokens.
pub struct Generator {
    vocab: usize,
    /// successors[t] = candidate next tokens for t
    successors: Vec<Vec<u32>>,
    unigram: ZipfTable,
    rng: Rng,
}

impl Generator {
    pub fn new(corpus: Corpus, vocab: usize, stream_seed: u64) -> Generator {
        // corpus structure is a pure function of (corpus, vocab); the
        // stream seed only affects which sentences get sampled
        let mut structure_rng = Rng::new(corpus.seed() ^ (vocab as u64) << 17);
        let b = corpus.branching();
        let zipf = ZipfTable::new(vocab, corpus.zipf_exponent());
        let successors = (0..vocab)
            .map(|_| {
                (0..b)
                    .map(|_| zipf.sample(&mut structure_rng) as u32)
                    .collect()
            })
            .collect();
        Generator {
            vocab,
            successors,
            unigram: ZipfTable::new(vocab, corpus.zipf_exponent()),
            rng: Rng::new(stream_seed ^ corpus.seed().rotate_left(32)),
        }
    }

    /// Sample a sequence of `len` token ids.
    pub fn sequence(&mut self, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut cur = self.unigram.sample(&mut self.rng);
        out.push(cur as i32);
        for _ in 1..len {
            // mostly follow the chain; occasionally resample (sentence break)
            cur = if self.rng.f64() < 0.1 {
                self.unigram.sample(&mut self.rng)
            } else {
                *self.rng.choice(&self.successors[cur]) as usize
            };
            out.push(cur as i32);
        }
        out
    }

    /// (tokens, next-token targets) pair, shaped B x S flat, last target
    /// masked with -1.
    pub fn batch(&mut self, b: usize, s: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(b * s);
        let mut tgts = Vec::with_capacity(b * s);
        for _ in 0..b {
            let seq = self.sequence(s + 1);
            toks.extend_from_slice(&seq[..s]);
            tgts.extend_from_slice(&seq[1..=s]);
            *tgts.last_mut().unwrap() = seq[s];
        }
        (toks, tgts)
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_in_range_and_deterministic() {
        let mut g1 = Generator::new(Corpus::Wiki2, 256, 1);
        let mut g2 = Generator::new(Corpus::Wiki2, 256, 1);
        let a = g1.sequence(64);
        let b = g2.sequence(64);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| t >= 0 && (t as usize) < 256));
    }

    #[test]
    fn corpora_differ() {
        let a = Generator::new(Corpus::Wiki2, 256, 1).sequence(256);
        let b = Generator::new(Corpus::Ptb, 256, 1).sequence(256);
        assert_ne!(a, b);
    }

    #[test]
    fn chain_is_learnable_structure() {
        // successor sets are small, so bigram entropy << log2(vocab)
        let g = Generator::new(Corpus::Ptb, 256, 1);
        let distinct: usize = g.successors[0]
            .iter()
            .collect::<std::collections::BTreeSet<_>>()
            .len();
        assert!(distinct <= 6);
    }

    #[test]
    fn batch_shapes_and_shift() {
        let mut g = Generator::new(Corpus::C4, 128, 2);
        let (t, y) = g.batch(2, 16);
        assert_eq!(t.len(), 32);
        assert_eq!(y.len(), 32);
        // target s is token s+1 within each row
        assert_eq!(t[1], y[0]);
        assert_eq!(t[17], y[16]);
    }

    #[test]
    fn zipf_marginal_is_skewed() {
        let mut g = Generator::new(Corpus::Wiki2, 512, 3);
        let seq = g.sequence(20_000);
        let low = seq.iter().filter(|&&t| t < 25).count();
        assert!(low as f64 / 20_000.0 > 0.2, "{low}");
    }
}
