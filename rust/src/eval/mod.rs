//! Algorithm-side evaluation harness (the lm-eval-harness substitute):
//! synthetic corpora, calibration, per-method quantized evaluation,
//! zero-shot-style tasks, and the experiment registry that regenerates
//! every paper table/figure.

pub mod calibrate;
pub mod corpora;
pub mod experiments;
pub mod methods;
pub mod ppl;
pub mod tasks;

pub use calibrate::{calibrate, Calibration};
pub use corpora::{Corpus, Generator};
pub use experiments::{run as run_experiment, ExperimentCtx, ALL_IDS};
pub use methods::Method;
