//! Zero-shot-style synthetic tasks (Table IV substitute, DESIGN.md §1.3).
//!
//! Each task is a binary-choice likelihood comparison (the lm-eval-harness
//! scoring scheme behind PIQA/ARC/...): the model sees a context and must
//! assign a lower loss to the true continuation than to a distractor.
//! Six tasks probe different structure, mirroring the six Table IV suites.

use anyhow::Result;

use super::corpora::{Corpus, Generator};
use crate::runtime::{HostTensor, ParamSet, Runtime};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub enum Task {
    /// in-distribution continuation vs random token (PIQA stand-in)
    Continuation,
    /// chain-following vs chain-breaking successor (ARC-E stand-in)
    ChainStep,
    /// harder: distractor is a plausible but wrong successor (ARC-C)
    ChainStepHard,
    /// repeated-context recall: token seen earlier vs unseen (BoolQ)
    Recall,
    /// longer-range continuation over 2x context (HellaSwag)
    LongContinuation,
    /// frequent-vs-rare token prior (WinoGrande stand-in)
    FrequencyPrior,
}

impl Task {
    pub const ALL: [Task; 6] = [
        Task::Continuation,
        Task::ChainStep,
        Task::ChainStepHard,
        Task::Recall,
        Task::LongContinuation,
        Task::FrequencyPrior,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Task::Continuation => "Contin.",
            Task::ChainStep => "Chain-E",
            Task::ChainStepHard => "Chain-C",
            Task::Recall => "Recall",
            Task::LongContinuation => "LongCont",
            Task::FrequencyPrior => "FreqPrior",
        }
    }
}

/// One scored example: shared context, true vs distractor final token.
struct Example {
    tokens_true: Vec<i32>,
    tokens_false: Vec<i32>,
}

fn make_examples(task: Task, vocab: usize, seq: usize, n: usize, rng: &mut Rng) -> Vec<Example> {
    let mut gen = Generator::new(Corpus::Wiki2, vocab, 0x7A5C ^ task as u64);
    (0..n)
        .map(|_| {
            let ctx_len = match task {
                Task::LongContinuation => seq - 1,
                _ => seq / 2,
            };
            let s = gen.sequence(ctx_len + 1);
            let mut t_true = s.clone();
            let mut t_false = s.clone();
            let truth = s[ctx_len];
            let distract = match task {
                Task::FrequencyPrior => (vocab - 1 - rng.below(vocab / 8)) as i32,
                Task::Recall => {
                    // true = token from earlier in the context
                    let seen = s[rng.below(ctx_len.saturating_sub(1))];
                    t_true[ctx_len] = seen;
                    loop {
                        let cand = rng.below(vocab) as i32;
                        if !s[..ctx_len].contains(&cand) {
                            break cand;
                        }
                    }
                }
                Task::ChainStepHard => {
                    // a token that is frequent overall but not a successor
                    ((truth as usize + 1) % vocab) as i32
                }
                _ => rng.below(vocab) as i32,
            };
            t_false[ctx_len] = distract;
            let _ = truth;
            // pad to full seq
            t_true.resize(seq, 0);
            t_false.resize(seq, 0);
            Example { tokens_true: t_true, tokens_false: t_false }
        })
        .collect()
}

/// Score a task: fraction of examples where loss(true) < loss(false),
/// evaluated through the given artifact (None = FP loss_eval). Targets
/// mask everything except the answer position.
pub fn score_task(
    rt: &mut Runtime,
    artifact: Option<&str>,
    params: &ParamSet,
    extras: &[HostTensor],
    task: Task,
    n_examples: usize,
) -> Result<f64> {
    let m = rt.manifest.model;
    let mut rng = Rng::new(0x5C0E ^ task as u64);
    let examples = make_examples(task, m.vocab, m.seq_len, n_examples, &mut rng);
    let exe = rt.load(artifact.unwrap_or("loss_eval"))?;
    let ctx_len = match task {
        Task::LongContinuation => m.seq_len - 1,
        _ => m.seq_len / 2,
    };

    let mut correct = 0usize;
    // batch the artifact's fixed (B, S): score examples one per batch row
    let b = m.batch;
    let mut scores: Vec<(f64, f64)> = Vec::with_capacity(examples.len());
    let run_variant = |toks: &[Vec<i32>]| -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(toks.len());
        for chunk in toks.chunks(b) {
            let mut flat_t = Vec::with_capacity(b * m.seq_len);
            let mut flat_y = vec![-1i32; b * m.seq_len];
            for (row, tk) in chunk.iter().enumerate() {
                flat_t.extend_from_slice(tk);
                // target: predict the answer token from position ctx_len-1
                flat_y[row * m.seq_len + ctx_len - 1] = tk[ctx_len];
            }
            // pad the batch with copies of row 0
            for _ in chunk.len()..b {
                flat_t.extend_from_slice(&chunk[0]);
            }
            let mut inputs = params.tensors.clone();
            inputs.extend(extras.iter().cloned());
            inputs.push(HostTensor::i32(flat_t, &[b, m.seq_len]));
            inputs.push(HostTensor::i32(flat_y, &[b, m.seq_len]));
            let o = exe.run(&inputs)?;
            // mean over the unmasked positions == mean over chunk answers;
            // to score per-example we need per-example losses, so run with
            // one live row at a time... instead we exploit linearity by
            // scoring each example in its own batch row set. For batch
            // efficiency we accept chunk-mean scoring when chunk == 1.
            out.push(o[0].as_f32()?[0] as f64);
        }
        Ok(out)
    };

    // score example-by-example (B rows hold the same example for exactness)
    for ex in &examples {
        let lt = run_variant(&vec![ex.tokens_true.clone(); 1])?[0];
        let lf = run_variant(&vec![ex.tokens_false.clone(); 1])?[0];
        scores.push((lt, lf));
        if lt < lf {
            correct += 1;
        }
    }
    let _ = scores;
    Ok(correct as f64 / examples.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn examples_differ_only_at_answer() {
        let mut rng = Rng::new(1);
        for task in Task::ALL {
            let ex = make_examples(task, 256, 32, 4, &mut rng);
            for e in ex {
                let diff: Vec<usize> = (0..32)
                    .filter(|&i| e.tokens_true[i] != e.tokens_false[i])
                    .collect();
                assert!(diff.len() <= 2, "{task:?}: {diff:?}");
            }
        }
    }

    #[test]
    fn task_names_unique() {
        let names: std::collections::BTreeSet<_> =
            Task::ALL.iter().map(|t| t.name()).collect();
        assert_eq!(names.len(), 6);
    }
}
