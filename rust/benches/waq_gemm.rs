//! L1/L3 hot-path bench: the WAQ GEMM along every execution path —
//! Rust software datapath (direct / histogram / dual-branch / packed
//! fused-pair-LUT), the tiled+threaded continuous-batch kernel, the
//! blocked f32 SGEMM baseline, and the compiled Pallas artifact through
//! PJRT (when built with `--features pjrt` and artifacts exist).
//!
//! Also sweeps the any-bit datapath: per-width packed-kernel rows tagged
//! `wbits`/`group_size`, plus the bit-planner tripwire — the `--wbits
//! auto` plan at budget 3.0, solved on *measured* per-linear output MSE,
//! must never be less accurate than uniform 3-bit at the same average
//! width (the bench fails the job when it is).
//!
//! Results append to BENCH_waq_gemm.json at the repo root (JSON lines) so
//! the perf trajectory is tracked across PRs.

use kllm::gemm::{self, CartesianLut, TileCfg, WaqBackend, WaqGemm};
use kllm::quant::{self, OutlierCfg, QuantToken, QuantWeights};
use kllm::runtime::{artifacts_dir, pjrt_available, HostTensor, Runtime};
use kllm::tensor::Matrix;
use kllm::util::bench::{bench_json_path, black_box, fast_mode, BenchResult, Bencher};
use kllm::util::rng::Rng;

const JSON: &str = "BENCH_waq_gemm.json";

/// Output-MSE of `calib @ dequant(quantize(w, b))` against `calib @ w`
/// for b in {2,3,4} — the same sensitivity currency the serving-side
/// `--wbits auto` planner measures during calibration.
fn width_mse(w: &Matrix, calib: &Matrix, group: usize) -> [f64; 3] {
    let want = calib.matmul(w);
    let mut out = [0f64; 3];
    for (slot, bits) in [2u32, 3, 4].into_iter().enumerate() {
        let deq = quant::quantize_weights_grouped(w, None, bits, group).dequantize();
        let got = calib.matmul(&deq);
        let err: f64 = want
            .data
            .iter()
            .zip(&got.data)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum();
        out[slot] = err / want.data.len() as f64;
    }
    out
}

fn main() -> anyhow::Result<()> {
    let (k, n) = if fast_mode() { (256, 256) } else { (1024, 1024) };
    let mut rng = Rng::new(1);
    let w = Matrix::random_normal(k, n, 1.0, &mut rng);
    let qw = quant::quantize_weights(&w, 4);
    let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(k, 1.0)).collect();
    let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
    let cb_a = quant::learn_act_codebook(&refs, None, 4, OutlierCfg::default());
    let x = rng.normal_vec(k, 1.0);
    let tok = quant::quantize_token(&x, &cb_a, OutlierCfg::default());
    let lut = CartesianLut::build(&cb_a, &qw.codebook);
    let pw = qw.pack();

    println!("== WAQ GEMM hot path (K={k}, N={n}) ==");
    let b = Bencher::default().throughput((k * n) as u64).json(JSON);
    let direct = b.run("rust direct (software datapath)", || {
        black_box(gemm::execute_direct(&tok, &qw, &lut));
    });
    b.run("rust histogram (index-counter semantics)", || {
        black_box(gemm::execute_histogram(&tok, &qw, &lut));
    });
    b.run("rust dual-branch", || {
        black_box(gemm::execute_dual_branch(&tok, &qw, &lut));
    });
    let packed = b.run("rust packed (fused pair-LUT, nibble idx)", || {
        black_box(gemm::execute_packed(&tok, &pw, &lut));
    });
    println!(
        "-- packed vs direct single-token speedup: {:.2}x (target >= 2x)",
        direct.mean_ns / packed.mean_ns
    );
    let xm = Matrix::from_vec(1, k, x.clone());
    b.run("blocked f32 sgemm (tensor::matmul)", || {
        black_box(xm.matmul(&w));
    });

    // continuous-batch decode: per-token direct vs tiled+threaded packed
    for batch in [1usize, 4, 8, 16] {
        let toks: Vec<QuantToken> = (0..batch)
            .map(|_| {
                quant::quantize_token(&rng.normal_vec(k, 1.0), &cb_a, OutlierCfg::default())
            })
            .collect();
        let bb = Bencher::default()
            .throughput((batch * k * n) as u64)
            .json(JSON);
        let per_tok = bb.run(&format!("batch{batch:<2} per-token execute_batch"), || {
            black_box(gemm::waq::execute_batch(&toks, &qw, &lut));
        });
        let tile = TileCfg::default();
        let tiled = bb.run(&format!("batch{batch:<2} execute_batch_tiled"), || {
            black_box(gemm::execute_batch_tiled(&toks, &pw, &lut, &tile));
        });
        let st = TileCfg::single_thread();
        bb.run(&format!("batch{batch:<2} tiled single-thread"), || {
            black_box(gemm::execute_batch_tiled(&toks, &pw, &lut, &st));
        });
        println!(
            "-- batch {batch}: tiled vs per-token speedup {:.2}x",
            per_tok.mean_ns / tiled.mean_ns
        );
    }

    // any-bit mixed precision: the one packed kernel at every weight
    // width × scale grid; rows are tagged `wbits`/`group_size` so the
    // trajectory separates the axes instead of overloading `name`
    println!("== any-bit packed kernel (K={k}, N={n}) ==");
    let json_path = bench_json_path(JSON);
    for wbits in [2u32, 3, 4] {
        for group in [0usize, 128] {
            let qwg = quant::quantize_weights_grouped(&w, None, wbits, group);
            let lutg = CartesianLut::build(&cb_a, &qwg.codebook);
            let pwg = qwg.pack();
            let bt = Bencher::quick().throughput((k * n) as u64);
            let mut r = bt.run(&format!("packed W{wbits} group={group}"), || {
                black_box(gemm::execute_packed(&tok, &pwg, &lutg));
            });
            r.extra = vec![
                ("wbits".into(), wbits.to_string()),
                ("group_size".into(), group.to_string()),
            ];
            r.append_json(&json_path);
        }
    }

    // bit-planner tripwire: measure the sensitivity of a 4-linear stack
    // with spread weight scales (spread sensitivities), solve the auto
    // plan at budget 3.0, and require (a) the parameter-weighted average
    // width stays inside the budget and (b) the plan's total measured
    // error never exceeds uniform 3-bit — the accuracy bar `--wbits auto`
    // ships under. The planner guards this by construction; the tripwire
    // keeps the guard from regressing.
    let (pk, pn) = if fast_mode() { (64, 32) } else { (256, 64) };
    let calib_m = Matrix::random_normal(8, pk, 1.0, &mut rng);
    let mut mse: Vec<[f64; 3]> = Vec::new();
    let mut sizes: Vec<usize> = Vec::new();
    for scale in [0.1f32, 0.5, 1.0, 3.0] {
        let lw = Matrix::random_normal(pk, pn, scale, &mut rng);
        mse.push(width_mse(&lw, &calib_m, 128));
        sizes.push(pk * pn);
    }
    let plan = quant::plan_bits(&mse, &sizes, 3.0);
    let plan_score =
        |p: &[u32]| -> f64 { p.iter().zip(&mse).map(|(&b, m)| m[b as usize - 2]).sum() };
    let auto_err = plan_score(&plan);
    let uni3_err = plan_score(&vec![3u32; mse.len()]);
    let avg_bits = plan.iter().map(|&b| b as f64).sum::<f64>() / plan.len() as f64;
    println!(
        "-- wbits auto plan {plan:?} (avg {avg_bits:.2} bits): \
         err {auto_err:.3e} vs uniform-3 {uni3_err:.3e}"
    );
    let mut row = BenchResult { name: "wbits auto plan (budget 3.0)".into(), ..Default::default() };
    let plan_str = plan.iter().map(|b| b.to_string()).collect::<Vec<_>>().join(",");
    row.extra = vec![
        ("wbits_plan".into(), format!("[{plan_str}]")),
        ("wbits_avg".into(), format!("{avg_bits:.4}")),
        ("auto_err".into(), format!("{auto_err:.6e}")),
        ("uniform3_err".into(), format!("{uni3_err:.6e}")),
    ];
    row.append_json(&json_path);
    anyhow::ensure!(
        avg_bits <= 3.0 + 1e-9,
        "auto plan {plan:?} busts the 3.0 average-bits budget"
    );
    anyhow::ensure!(
        auto_err <= uni3_err + 1e-12,
        "auto plan (err {auto_err:.3e}) lost to uniform 3-bit ({uni3_err:.3e}) \
         at equal average bits"
    );

    // the dispatch layer all serving paths go through
    for backend in WaqBackend::ALL {
        let g = WaqGemm::new(qw.clone(), lut.clone(), backend);
        let bb = Bencher::quick().throughput((k * n) as u64).json(JSON);
        bb.run(&format!("WaqGemm backend={}", backend.name()), || {
            black_box(g.execute(&tok));
        });
    }

    // quantization-side hot paths
    b.run("clustering unit assign (token)", || {
        let mut out = Vec::new();
        cb_a.assign_slice(black_box(&x), &mut out);
        black_box(out);
    });
    let bq = Bencher::default().json(JSON);
    bq.run("quantize_token (incl. outlier detect)", || {
        black_box(quant::quantize_token(&x, &cb_a, OutlierCfg::default()));
    });

    // PJRT artifact path (the fused Pallas kernel, interpret-lowered)
    let dir = artifacts_dir("test");
    if pjrt_available() && dir.join("manifest.json").exists() {
        let mut rt = Runtime::new(&dir)?;
        let spec = rt.manifest.artifact("waq_gemm").unwrap().clone();
        let (mm, kk, nn) = (
            spec.meta.get("M").unwrap().as_usize().unwrap(),
            spec.meta.get("K").unwrap().as_usize().unwrap(),
            spec.meta.get("N").unwrap().as_usize().unwrap(),
        );
        let a_idx: Vec<i32> = (0..mm * kk).map(|_| rng.below(16) as i32).collect();
        let w_idx: Vec<i32> = (0..kk * nn).map(|_| rng.below(16) as i32).collect();
        let inputs = vec![
            HostTensor::i32(a_idx, &[mm, kk]),
            HostTensor::i32(w_idx, &[kk, nn]),
            HostTensor::f32(cb_a.centroids.clone(), &[16]),
            HostTensor::f32(qw.codebook.centroids.clone(), &[16]),
            HostTensor::f32(vec![1.0; mm], &[mm]),
            HostTensor::f32(vec![1.0; nn], &[nn]),
        ];
        let exe = rt.load("waq_gemm")?;
        let bp = Bencher::default().throughput((mm * kk * nn) as u64).json(JSON);
        bp.run(&format!("pjrt waq_gemm artifact ({mm}x{kk}x{nn})"), || {
            black_box(exe.run(&inputs).unwrap());
        });
        let qw_small = QuantWeights {
            n_rows: kk,
            n_cols: nn,
            idx: inputs[1].as_i32().unwrap().iter().map(|&v| v as u8).collect(),
            codebook: qw.codebook.clone(),
            col_scales: vec![1.0; nn],
            group_size: 0,
            group_scales: vec![],
        };
        let tok_small = quant::QuantToken {
            idx: inputs[0].as_i32().unwrap()[..kk].iter().map(|&v| v as u8).collect(),
            scale: 1.0,
            outliers: vec![],
        };
        let lut_small = CartesianLut::build(&cb_a, &qw.codebook);
        bp.run("rust direct (same shape, per row)", || {
            black_box(gemm::execute_direct(&tok_small, &qw_small, &lut_small));
        });
        let pw_small = qw_small.pack();
        bp.run("rust packed (same shape, per row)", || {
            black_box(gemm::execute_packed(&tok_small, &pw_small, &lut_small));
        });
    } else if !pjrt_available() {
        println!("pjrt feature disabled — skipping artifact path");
    }
    Ok(())
}
