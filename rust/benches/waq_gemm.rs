//! L1/L3 hot-path bench: the WAQ GEMM along every execution path —
//! Rust software datapath (direct / histogram / dual-branch), the blocked
//! f32 SGEMM baseline, and the compiled Pallas artifact through PJRT.

use kllm::gemm::{self, CartesianLut};
use kllm::quant::{self, OutlierCfg, QuantWeights};
use kllm::runtime::{artifacts_dir, HostTensor, Runtime};
use kllm::tensor::Matrix;
use kllm::util::bench::{black_box, fast_mode, Bencher};
use kllm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let (k, n) = if fast_mode() { (256, 256) } else { (1024, 1024) };
    let mut rng = Rng::new(1);
    let w = Matrix::random_normal(k, n, 1.0, &mut rng);
    let qw = quant::quantize_weights(&w, 4);
    let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(k, 1.0)).collect();
    let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
    let cb_a = quant::learn_act_codebook(&refs, None, 4, OutlierCfg::default());
    let x = rng.normal_vec(k, 1.0);
    let tok = quant::quantize_token(&x, &cb_a, OutlierCfg::default());
    let lut = CartesianLut::build(&cb_a, &qw.codebook);

    println!("== WAQ GEMM hot path (K={k}, N={n}) ==");
    let b = Bencher::default().throughput((k * n) as u64);
    b.run("rust direct (software datapath)", || {
        black_box(gemm::execute_direct(&tok, &qw, &lut));
    });
    b.run("rust histogram (index-counter semantics)", || {
        black_box(gemm::execute_histogram(&tok, &qw, &lut));
    });
    b.run("rust dual-branch", || {
        black_box(gemm::execute_dual_branch(&tok, &qw, &lut));
    });
    let xm = Matrix::from_vec(1, k, x.clone());
    b.run("blocked f32 sgemm (tensor::matmul)", || {
        black_box(xm.matmul(&w));
    });

    // quantization-side hot paths
    b.run("clustering unit assign (token)", || {
        let mut out = Vec::new();
        cb_a.assign_slice(black_box(&x), &mut out);
        black_box(out);
    });
    let bq = Bencher::default();
    bq.run("quantize_token (incl. outlier detect)", || {
        black_box(quant::quantize_token(&x, &cb_a, OutlierCfg::default()));
    });

    // PJRT artifact path (the fused Pallas kernel, interpret-lowered)
    let dir = artifacts_dir("test");
    if dir.join("manifest.json").exists() {
        let mut rt = Runtime::new(&dir)?;
        let spec = rt.manifest.artifact("waq_gemm").unwrap().clone();
        let (mm, kk, nn) = (
            spec.meta.get("M").unwrap().as_usize().unwrap(),
            spec.meta.get("K").unwrap().as_usize().unwrap(),
            spec.meta.get("N").unwrap().as_usize().unwrap(),
        );
        let a_idx: Vec<i32> = (0..mm * kk).map(|_| rng.below(16) as i32).collect();
        let w_idx: Vec<i32> = (0..kk * nn).map(|_| rng.below(16) as i32).collect();
        let inputs = vec![
            HostTensor::i32(a_idx, &[mm, kk]),
            HostTensor::i32(w_idx, &[kk, nn]),
            HostTensor::f32(cb_a.centroids.clone(), &[16]),
            HostTensor::f32(qw.codebook.centroids.clone(), &[16]),
            HostTensor::f32(vec![1.0; mm], &[mm]),
            HostTensor::f32(vec![1.0; nn], &[nn]),
        ];
        let exe = rt.load("waq_gemm")?;
        let bp = Bencher::default().throughput((mm * kk * nn) as u64);
        bp.run(&format!("pjrt waq_gemm artifact ({mm}x{kk}x{nn})"), || {
            black_box(exe.run(&inputs).unwrap());
        });
        let qw_small = QuantWeights {
            n_rows: kk,
            n_cols: nn,
            idx: inputs[1].as_i32().unwrap().iter().map(|&v| v as u8).collect(),
            codebook: qw.codebook.clone(),
            col_scales: vec![1.0; nn],
        };
        let tok_small = quant::QuantToken {
            idx: inputs[0].as_i32().unwrap()[..kk].iter().map(|&v| v as u8).collect(),
            scale: 1.0,
            outliers: vec![],
        };
        let lut_small = CartesianLut::build(&cb_a, &qw.codebook);
        bp.run("rust direct (same shape, per row)", || {
            black_box(gemm::execute_direct(&tok_small, &qw_small, &lut_small));
        });
    }
    Ok(())
}
