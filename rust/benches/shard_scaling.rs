//! Shard-scaling bench: tensor-parallel column sharding of the native WAQ
//! datapath, at two levels —
//!   * `shard_scaling/gemm/*`: one serving-shaped packed LUT-GEMM split
//!     into S shards on the persistent pool (the scaling story the column
//!     split is responsible for);
//!   * `shard_scaling/e2e/*`: whole-engine decode throughput through
//!     `--backend native-sharded` on the test preset with a 4-bit KV
//!     cache.
//!
//! Rows land in BENCH_shard.json (`util::bench::ShardBenchRow` documents
//! the schema). Two CI tripwires fail the job when they fire:
//!   * parity — sharded output must be bit-exact with the unsharded
//!     packed kernel (GEMM level) and sharded serving must produce the
//!     exact greedy token streams of `native-packed` (e2e level);
//!   * scaling — with >= 4 logical CPUs, serving-scale GEMM time is
//!     monotonically non-increasing from 1 -> 4 shards (5% noise floor);
//!     the hard >= 1.5x bound at 4 shards arms at >= 8 logical CPUs
//!     (>= 4 physical cores under SMT-2 — a 2-core/4-thread runner
//!     cannot reach it); and 4-shard e2e serving on the tiny preset may
//!     not collapse below half of 1-shard throughput (the preset's
//!     narrow linears sit below the fused-build amortization point, so
//!     e2e *speedup* is asserted at GEMM scale).
//!
//! FAST_BENCH=1 sweeps shards {1, 4} on a smaller shape; the full run
//! sweeps {1, 2, 4, 8}.

use std::sync::Arc;
use std::time::Instant;

use kllm::coordinator::{AdmitPolicy, BackendSpec, Coordinator, EngineConfig};
use kllm::gemm::{
    compensate_packed, execute_batch_tiled, CartesianLut, ShardPool, ShardedWaqGemm, TileCfg,
    WaqBackend,
};
use kllm::kvcache::KvBits;
use kllm::quant::{self, OutlierCfg, QuantToken};
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::tensor::Matrix;
use kllm::util::bench::{fast_mode, ShardBenchRow};
use kllm::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let shard_counts: &[usize] = if fast_mode() { &[1, 4] } else { &[1, 2, 4, 8] };
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    gemm_scaling(shard_counts, cores)?;
    e2e_scaling(shard_counts, cores)?;
    Ok(())
}

/// Serving-shaped sharded GEMM: parity tripwire + scaling measurement.
fn gemm_scaling(shard_counts: &[usize], cores: usize) -> anyhow::Result<()> {
    let (k, n, batch, reps) = if fast_mode() {
        (384usize, 1024usize, 4usize, 40usize)
    } else {
        (768, 4096, 8, 60)
    };
    let mut rng = Rng::new(0x5A4D);
    let wmat = Matrix::random_normal(k, n, 1.0, &mut rng);
    let qw = quant::quantize_weights(&wmat, 4);
    let calib: Vec<Vec<f32>> = (0..6).map(|_| rng.heavy_tailed_vec(k, 0.02, 8.0)).collect();
    let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
    let ocfg = OutlierCfg::default();
    let cb = quant::learn_act_codebook(&refs, None, 4, ocfg);
    let toks: Vec<QuantToken> = (0..batch)
        .map(|_| quant::quantize_token(&rng.heavy_tailed_vec(k, 0.02, 8.0), &cb, ocfg))
        .collect();
    let lut = CartesianLut::build(&cb, &qw.codebook);
    let pw = qw.pack();

    // unsharded reference: packed kernel + outlier compensation (the
    // bit-exactness oracle every shard count must reproduce)
    let mut want = execute_batch_tiled(&toks, &pw, &lut, &TileCfg::single_thread());
    for (o, t) in want.iter_mut().zip(&toks) {
        compensate_packed(o, t, &pw);
    }

    let name = format!("shard_scaling/gemm/k{k}n{n}b{batch}");
    let mut best_by_shards: Vec<(usize, f64)> = Vec::new();
    for &s in shard_counts {
        let pool = Arc::new(ShardPool::new(s).map_err(anyhow::Error::msg)?);
        let sharded =
            ShardedWaqGemm::from_packed(&pw, &lut, s, pool).map_err(anyhow::Error::msg)?;
        // parity tripwire (always enforced, any core count)
        assert_eq!(
            sharded.execute_batch(&toks),
            want,
            "{s}-shard GEMM diverged from the unsharded packed kernel"
        );
        let mut out: Vec<Vec<f32>> = toks.iter().map(|_| vec![0.0f32; n]).collect();
        for _ in 0..3 {
            sharded.execute_batch_into(&toks, &mut out);
        }
        let (mut best, mut total) = (f64::INFINITY, 0.0f64);
        for _ in 0..reps {
            let t0 = Instant::now();
            sharded.execute_batch_into(&toks, &mut out);
            let dt = t0.elapsed().as_secs_f64();
            best = best.min(dt);
            total += dt;
        }
        let t1_best = best_by_shards.first().map(|&(_, b)| b).unwrap_or(best);
        let speedup = t1_best / best;
        let row = ShardBenchRow {
            name: name.clone(),
            shards: s as u32,
            tok_s: batch as f64 / best,
            mean_ns: total / reps as f64 * 1e9,
            speedup_vs_1: speedup,
            efficiency: speedup / s as f64,
        };
        println!(
            "bench {:34} shards={s} best {:9.3} ms  {:9.1} tok/s  speedup {:.2}x  eff {:.2}",
            row.name,
            best * 1e3,
            row.tok_s,
            row.speedup_vs_1,
            row.efficiency
        );
        row.append();
        best_by_shards.push((s, best));
    }

    // scaling tripwires. `available_parallelism` counts SMT threads, not
    // physical cores, and a 4-thread/2-core runner genuinely cannot reach
    // 1.5x (the replicated fused-table build means 4 shards do ~1.6x the
    // single-shard work; on 2 real cores that nets ~1.25x) — so the hard
    // 1.5x bound only arms at >= 8 logical CPUs (>= 4 physical under
    // SMT-2), and 4..8-logical hosts get the monotonicity checks alone.
    let best = |c: usize| best_by_shards.iter().find(|&&(s, _)| s == c).map(|&(_, b)| b);
    match (best(1), best(4)) {
        (Some(t1), Some(t4)) if cores >= 4 => {
            let speedup = t1 / t4;
            if cores >= 8 {
                assert!(
                    speedup >= 1.5,
                    "4-shard speedup {speedup:.2}x < 1.5x on a {cores}-logical-CPU host"
                );
            }
            if let Some(t2) = best(2) {
                // tok/s monotonically non-decreasing from 1 -> 4 shards
                // (5% timing-noise floor on best-of-N times)
                assert!(t2 <= t1 * 1.05, "2-shard time regressed vs 1 shard: {t2} vs {t1}");
                assert!(t4 <= t2 * 1.05, "4-shard time regressed vs 2 shards: {t4} vs {t2}");
            } else {
                assert!(t4 <= t1 * 1.05, "4-shard time regressed vs 1 shard: {t4} vs {t1}");
            }
        }
        _ => println!("(skipping scaling assertions: {cores} logical CPUs available)"),
    }
    Ok(())
}

/// One serving run: submit a seeded greedy burst, drain, return the
/// per-request token streams (sorted by id), wall seconds, and tokens.
fn run_serving(
    manifest: &Manifest,
    params: &ParamSet,
    backend: BackendSpec,
    shards: usize,
    n_requests: usize,
    max_new: usize,
) -> anyhow::Result<(Vec<(u64, Vec<i32>)>, f64, usize)> {
    let coord = Coordinator::start_with_manifest(
        manifest.clone(),
        ParamSet { tensors: params.tensors.clone() },
        EngineConfig {
            policy: AdmitPolicy::FillAll,
            backend,
            kv_bits: KvBits::B4,
            shards,
            ..Default::default()
        },
    )?;
    let vocab = manifest.model.vocab;
    let mut rng = Rng::new(3);
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..n_requests)
        .map(|_| {
            let prompt: Vec<i32> = (0..4).map(|_| rng.below(vocab) as i32).collect();
            coord.submit_async(prompt, max_new, 0.0).unwrap()
        })
        .collect();
    let mut done = Vec::new();
    let mut tokens = 0usize;
    for (id, rx) in rxs {
        let r = rx.recv()?;
        tokens += r.tokens.len();
        done.push((id, r.tokens));
    }
    let wall = t0.elapsed().as_secs_f64();
    done.sort_by_key(|&(id, _)| id);
    coord.shutdown()?;
    Ok((done, wall, tokens))
}

/// Whole-engine decode through `--backend native-sharded` (4-bit cache):
/// e2e parity tripwire vs `native-packed`, plus BENCH_shard.json rows.
///
/// The *scaling* acceptance (monotone tok/s, >= 1.5x at 4 shards) is
/// asserted on the serving-scale GEMM rows above: the test preset's
/// linear widths (64-256 columns) sit below the fused-table build's
/// amortization point (see `gemm::sharded`'s "Scaling limit"), so tiny-
/// preset e2e rows are informational. What IS asserted here, beyond
/// bit-exact parity, is a catastrophic-regression guard: with enough
/// cores, 4-shard serving may not fall below half of 1-shard throughput
/// (catches pool/latch pathologies without demanding speedup on shapes
/// that cannot provide it).
fn e2e_scaling(shard_counts: &[usize], cores: usize) -> anyhow::Result<()> {
    let cfg = ModelCfg::test_preset();
    let manifest = Manifest::synthetic("test", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let n_requests = if fast_mode() { 6 } else { 16 };
    let max_new = 8;

    // unsharded greedy reference (same burst, same seeds)
    let (reference, _, _) = run_serving(
        &manifest,
        &params,
        BackendSpec::Native(WaqBackend::Packed),
        1,
        n_requests,
        max_new,
    )?;

    let mut t1_per_tok = None;
    for &s in shard_counts {
        let (streams, wall, tokens) =
            run_serving(&manifest, &params, BackendSpec::NativeSharded, s, n_requests, max_new)?;
        // e2e parity tripwire: bit-exact greedy token streams
        assert_eq!(
            streams, reference,
            "{s}-shard serving diverged from native-packed greedy decode"
        );
        let per_tok = wall / tokens.max(1) as f64;
        let t1 = *t1_per_tok.get_or_insert(per_tok);
        let speedup = t1 / per_tok;
        let row = ShardBenchRow {
            name: "shard_scaling/e2e/test".into(),
            shards: s as u32,
            tok_s: tokens as f64 / wall,
            mean_ns: per_tok * 1e9,
            speedup_vs_1: speedup,
            efficiency: speedup / s as f64,
        };
        println!(
            "bench {:34} shards={s} {:9.1} tok/s  speedup {:.2}x  eff {:.2}",
            row.name, row.tok_s, row.speedup_vs_1, row.efficiency
        );
        row.append();
        if s == 4 && cores >= 4 {
            assert!(
                speedup >= 0.5,
                "4-shard e2e throughput collapsed to {speedup:.2}x of 1-shard on a \
                 {cores}-core host (pool/latch pathology)"
            );
        }
    }
    Ok(())
}
