//! End-to-end serving bench: coordinator throughput/latency on the test
//! preset across decode backends and admission policies.
//!
//! Native (`native-*`) runs execute the K-Means WAQ LUT-GEMM datapath and
//! always run — with a synthetic test-preset manifest when `make
//! artifacts` hasn't been built. PJRT runs need the `pjrt` feature plus
//! artifacts and are skipped otherwise. Each BENCH_e2e.json row is tagged
//! with the backend name so the perf trajectory keeps measured-native and
//! modeled-PJRT numbers separate: the wall-clock row is
//! `e2e_serving/<policy>/<backend>`, and the host-datapath row is
//! `.../measured-host` (native, real seconds) or `.../modeled-host`
//! (PJRT, CpuWaqModel roofline).

use kllm::coordinator::{AdmitPolicy, BackendSpec, Coordinator, EngineConfig};
use kllm::gemm::WaqBackend;
use kllm::kvcache::KvBits;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{artifacts_dir, pjrt_available, Manifest, ParamSet};
use kllm::util::bench::{bench_json_path, fast_mode, BenchResult};
use kllm::util::rng::Rng;
use kllm::util::stats::LatencyStats;

fn policy_name(p: AdmitPolicy) -> &'static str {
    match p {
        AdmitPolicy::OnePerStep => "decode-priority",
        AdmitPolicy::FillAll => "fill-all",
    }
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir("test");
    let have_artifacts = dir.join("manifest.json").exists();
    let manifest = if have_artifacts {
        Manifest::load(&dir).map_err(anyhow::Error::msg)?
    } else {
        println!("artifacts/test missing — native runs use a synthetic manifest");
        Manifest::synthetic("test", ModelCfg::test_preset())
    };
    let cfg = manifest.model;
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let n_requests = if fast_mode() { 6 } else { 24 };
    let max_new = 8;
    let json = bench_json_path("BENCH_e2e.json");

    // native runs: the measured LUT-GEMM serving path — policy sweep on
    // the packed kernel, a packed-vs-direct kernel comparison, a KV
    // precision sweep (32 vs 4 bit cache; FAST_BENCH keeps both so CI
    // smoke-tests the quantized cache end to end), and the
    // tensor-parallel sharded backend (4 column shards; bit-exact with
    // native-packed, measured here for the serving-throughput trajectory)
    let mut runs: Vec<(AdmitPolicy, BackendSpec, KvBits)> = vec![
        (AdmitPolicy::OnePerStep, BackendSpec::Native(WaqBackend::Packed), KvBits::Fp32),
        (AdmitPolicy::FillAll, BackendSpec::Native(WaqBackend::Packed), KvBits::Fp32),
        (AdmitPolicy::FillAll, BackendSpec::Native(WaqBackend::Packed), KvBits::B4),
        (AdmitPolicy::FillAll, BackendSpec::Native(WaqBackend::Direct), KvBits::Fp32),
        (AdmitPolicy::FillAll, BackendSpec::NativeSharded, KvBits::Fp32),
        (AdmitPolicy::FillAll, BackendSpec::NativeSharded, KvBits::B4),
    ];
    if pjrt_available() && have_artifacts {
        // PJRT runs: measured wall-clock is artifact-bound; the modeled
        // host rows expose the packed kernel's decode advantage
        runs.push((AdmitPolicy::OnePerStep, BackendSpec::Pjrt(WaqBackend::Packed), KvBits::Fp32));
        runs.push((AdmitPolicy::FillAll, BackendSpec::Pjrt(WaqBackend::Packed), KvBits::Fp32));
        runs.push((AdmitPolicy::FillAll, BackendSpec::Pjrt(WaqBackend::Direct), KvBits::Fp32));
        runs.push((AdmitPolicy::FillAll, BackendSpec::Pjrt(WaqBackend::Histogram), KvBits::Fp32));
    } else {
        println!("pjrt feature/artifacts unavailable — skipping PJRT backend runs");
    }

    for (policy, backend, kv_bits) in runs {
        let name = format!("{}/{}/kv{}", policy_name(policy), backend.name(), kv_bits);
        let coord = Coordinator::start_with_manifest(
            manifest.clone(),
            ParamSet { tensors: params.tensors.clone() },
            EngineConfig { policy, backend, kv_bits, shards: 4, ..Default::default() },
        )?;
        let mut rng = Rng::new(3);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n_requests)
            .map(|_| {
                let prompt: Vec<i32> =
                    (0..4).map(|_| rng.below(cfg.vocab) as i32).collect();
                coord.submit_async(prompt, max_new, 0.0).unwrap().1
            })
            .collect();
        let mut lat = LatencyStats::default();
        let mut tokens = 0;
        for rx in rxs {
            let r = rx.recv()?;
            tokens += r.tokens.len();
            lat.record_us(r.total_s * 1e6);
        }
        let wall = t0.elapsed().as_secs_f64();
        let (stats, sim) = coord.stats()?;
        let summary = lat.summary();
        let host_kind = if backend.is_native() { "measured" } else { "modeled" };
        println!(
            "bench e2e_serving/{name:28} {:8.1} tok/s  occupancy {:.2}  {}  \
             modeled-OASIS {:.2} ms  {host_kind}-host[{}] {:.2} ms  kv {}b peak {} B",
            tokens as f64 / wall,
            stats.mean_occupancy(),
            summary,
            sim.seconds * 1e3,
            stats.waq_backend,
            stats.host_waq_s * 1e3,
            stats.kv_bits,
            stats.peak_kv_bytes,
        );
        // every row is tagged with the cache precision and its peak
        // footprint so the perf trajectory captures the memory axis too
        let kv_extra = vec![
            ("kv_bits".to_string(), stats.kv_bits.to_string()),
            ("peak_kv_bytes".to_string(), stats.peak_kv_bytes.to_string()),
        ];
        // one JSON row of measured per-token wall clock (mean == p50 == min:
        // only the aggregate is observable here), and a separate row for the
        // host-datapath per-token cost — measured for native backends,
        // modeled for PJRT — so the two trajectories stay semantically
        // distinct in BENCH_e2e.json
        let tok_ns = wall * 1e9 / (tokens.max(1) as f64);
        BenchResult {
            name: format!("e2e_serving/{name}"),
            iters: tokens as u64,
            mean_ns: tok_ns,
            p50_ns: tok_ns,
            min_ns: tok_ns,
            throughput: Some(tokens as f64 / wall),
            extra: kv_extra.clone(),
        }
        .append_json(&json);
        let host_ns = stats.host_waq_s * 1e9 / (tokens.max(1) as f64);
        BenchResult {
            name: format!("e2e_serving/{name}/{host_kind}-host"),
            iters: tokens as u64,
            mean_ns: host_ns,
            p50_ns: host_ns,
            min_ns: host_ns,
            throughput: None,
            extra: kv_extra,
        }
        .append_json(&json);
        coord.shutdown()?;
    }
    Ok(())
}
