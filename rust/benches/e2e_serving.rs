//! End-to-end serving bench: coordinator throughput/latency on the test
//! preset across decode backends and admission policies.
//!
//! Native (`native-*`) runs execute the K-Means WAQ LUT-GEMM datapath and
//! always run — with a synthetic test-preset manifest when `make
//! artifacts` hasn't been built. PJRT runs need the `pjrt` feature plus
//! artifacts and are skipped otherwise. Each BENCH_e2e.json row is tagged
//! with the backend name so the perf trajectory keeps measured-native and
//! modeled-PJRT numbers separate: the wall-clock row is
//! `e2e_serving/<policy>/<backend>`, and the host-datapath row is
//! `.../measured-host` (native, real seconds) or `.../modeled-host`
//! (PJRT, CpuWaqModel roofline). A burst-admission sweep additionally
//! compares 8 sequential prefills against one batched `prefill_batch`
//! call (BENCH_prefill.json, schema on `util::bench::PrefillBenchRow`),
//! asserting per-request bit-exactness and the sharded backend's
//! batched-is-faster property.

use kllm::coordinator::{
    AdmitPolicy, BackendSpec, Coordinator, DecodeBackend, EngineConfig, NativeCfg,
    NativeWaqBackend, ShardedWaqBackend,
};
use kllm::gemm::WaqBackend;
use kllm::kvcache::KvBits;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{artifacts_dir, pjrt_available, Manifest, ParamSet};
use kllm::util::bench::{bench_json_path, fast_mode, BenchResult, PrefillBenchRow};
use kllm::util::rng::Rng;
use kllm::util::stats::LatencyStats;

fn policy_name(p: AdmitPolicy) -> &'static str {
    match p {
        AdmitPolicy::OnePerStep => "decode-priority",
        AdmitPolicy::FillAll => "fill-all",
    }
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir("test");
    let have_artifacts = dir.join("manifest.json").exists();
    let manifest = if have_artifacts {
        Manifest::load(&dir).map_err(anyhow::Error::msg)?
    } else {
        println!("artifacts/test missing — native runs use a synthetic manifest");
        Manifest::synthetic("test", ModelCfg::test_preset())
    };
    let cfg = manifest.model;
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let n_requests = if fast_mode() { 6 } else { 24 };
    let max_new = 8;
    let json = bench_json_path("BENCH_e2e.json");

    // native runs: the measured LUT-GEMM serving path — policy sweep on
    // the packed kernel, a packed-vs-direct kernel comparison, a KV
    // precision sweep (32 vs 4 bit cache; FAST_BENCH keeps both so CI
    // smoke-tests the quantized cache end to end), and the
    // tensor-parallel sharded backend (4 column shards; bit-exact with
    // native-packed, measured here for the serving-throughput trajectory)
    let mut runs: Vec<(AdmitPolicy, BackendSpec, KvBits)> = vec![
        (AdmitPolicy::OnePerStep, BackendSpec::Native(WaqBackend::Packed), KvBits::Fp32),
        (AdmitPolicy::FillAll, BackendSpec::Native(WaqBackend::Packed), KvBits::Fp32),
        (AdmitPolicy::FillAll, BackendSpec::Native(WaqBackend::Packed), KvBits::B4),
        (AdmitPolicy::FillAll, BackendSpec::Native(WaqBackend::Direct), KvBits::Fp32),
        (AdmitPolicy::FillAll, BackendSpec::NativeSharded, KvBits::Fp32),
        (AdmitPolicy::FillAll, BackendSpec::NativeSharded, KvBits::B4),
    ];
    if pjrt_available() && have_artifacts {
        // PJRT runs: measured wall-clock is artifact-bound; the modeled
        // host rows expose the packed kernel's decode advantage
        runs.push((AdmitPolicy::OnePerStep, BackendSpec::Pjrt(WaqBackend::Packed), KvBits::Fp32));
        runs.push((AdmitPolicy::FillAll, BackendSpec::Pjrt(WaqBackend::Packed), KvBits::Fp32));
        runs.push((AdmitPolicy::FillAll, BackendSpec::Pjrt(WaqBackend::Direct), KvBits::Fp32));
        runs.push((AdmitPolicy::FillAll, BackendSpec::Pjrt(WaqBackend::Histogram), KvBits::Fp32));
    } else {
        println!("pjrt feature/artifacts unavailable — skipping PJRT backend runs");
    }

    for (policy, backend, kv_bits) in runs {
        let name = format!("{}/{}/kv{}", policy_name(policy), backend.name(), kv_bits);
        let coord = Coordinator::start_with_manifest(
            manifest.clone(),
            ParamSet { tensors: params.tensors.clone() },
            EngineConfig { policy, backend, kv_bits, shards: 4, ..Default::default() },
        )?;
        let mut rng = Rng::new(3);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n_requests)
            .map(|_| {
                let prompt: Vec<i32> =
                    (0..4).map(|_| rng.below(cfg.vocab) as i32).collect();
                coord.submit_async(prompt, max_new, 0.0).unwrap().1
            })
            .collect();
        let mut lat = LatencyStats::default();
        let mut tokens = 0;
        for rx in rxs {
            let r = rx.recv()?;
            tokens += r.tokens.len();
            lat.record_us(r.total_s * 1e6);
        }
        let wall = t0.elapsed().as_secs_f64();
        let (stats, sim) = coord.stats()?;
        let summary = lat.summary();
        let host_kind = if backend.is_native() { "measured" } else { "modeled" };
        println!(
            "bench e2e_serving/{name:28} {:8.1} tok/s  occupancy {:.2}  {}  \
             modeled-OASIS {:.2} ms  {host_kind}-host[{}] {:.2} ms  kv {}b peak {} B",
            tokens as f64 / wall,
            stats.mean_occupancy(),
            summary,
            sim.seconds * 1e3,
            stats.waq_backend,
            stats.host_waq_s * 1e3,
            stats.kv_bits,
            stats.peak_kv_bytes,
        );
        // every row is tagged with the cache precision and its peak
        // footprint so the perf trajectory captures the memory axis too
        let kv_extra = vec![
            ("kv_bits".to_string(), stats.kv_bits.to_string()),
            ("peak_kv_bytes".to_string(), stats.peak_kv_bytes.to_string()),
        ];
        // one JSON row of measured per-token wall clock (mean == p50 == min:
        // only the aggregate is observable here), and a separate row for the
        // host-datapath per-token cost — measured for native backends,
        // modeled for PJRT — so the two trajectories stay semantically
        // distinct in BENCH_e2e.json
        let tok_ns = wall * 1e9 / (tokens.max(1) as f64);
        BenchResult {
            name: format!("e2e_serving/{name}"),
            iters: tokens as u64,
            mean_ns: tok_ns,
            p50_ns: tok_ns,
            min_ns: tok_ns,
            throughput: Some(tokens as f64 / wall),
            extra: kv_extra.clone(),
        }
        .append_json(&json);
        let host_ns = stats.host_waq_s * 1e9 / (tokens.max(1) as f64);
        // native host seconds cover decode + prefill since the batched
        // admission path started measuring prefill; the tag keeps the
        // trajectory honest against older decode-only rows and the
        // PJRT modeled rows (whose clock still covers decode only)
        let mut host_extra = kv_extra;
        host_extra.push((
            "host_scope".to_string(),
            if backend.is_native() { "\"decode+prefill\"" } else { "\"decode\"" }.to_string(),
        ));
        BenchResult {
            name: format!("e2e_serving/{name}/{host_kind}-host"),
            iters: tokens as u64,
            mean_ns: host_ns,
            p50_ns: host_ns,
            min_ns: host_ns,
            throughput: None,
            extra: host_extra,
        }
        .append_json(&json);
        coord.shutdown()?;
    }

    burst_admission_sweep(&manifest, &params)?;
    Ok(())
}

/// Burst-admission prefill sweep: one FillAll-style 8-request burst
/// prefilled two ways on the same quantized model — 8 sequential
/// `DecodeBackend::prefill` calls vs ONE `prefill_batch` call (the
/// engine's admission path). Per-request logits must be bit-exact across
/// the two modes (the parity acceptance criterion, asserted here as a
/// tripwire too), and BENCH_prefill.json records the measured host-WAQ
/// seconds of both so the amortization win of running each WAQ LUT-GEMM
/// linear once per layer for the whole burst is tracked across PRs. The
/// sharded backend must complete the batched burst in strictly fewer
/// host-WAQ seconds (one worker-pool round per linear instead of eight);
/// the mono packed kernel's smaller fixed-overhead saving is recorded
/// without a strict gate (noise-prone on toy model sizes).
fn burst_admission_sweep(manifest: &Manifest, params: &ParamSet) -> anyhow::Result<()> {
    let cfg = manifest.model;
    let burst = 8usize;
    let plen = (cfg.seq_len / 2).max(1);
    let reps = if fast_mode() { 2 } else { 4 };
    let mut rng = Rng::new(17);
    let prompts: Vec<Vec<i32>> = (0..burst)
        .map(|_| (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect())
        .collect();
    let prompt_refs: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
    let prompt_tokens = (burst * plen) as u64;

    for backend_name in ["native-packed", "native-sharded"] {
        let mut b: Box<dyn DecodeBackend> = if backend_name == "native-sharded" {
            Box::new(ShardedWaqBackend::new(manifest, params, NativeCfg::default(), 4)?)
        } else {
            Box::new(NativeWaqBackend::new(
                manifest,
                params,
                NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() },
            )?)
        };
        // warm the datapath (first-touch allocations, branch predictors)
        let _ = b.prefill(&prompts[0])?;

        // min over reps per mode, so one descheduling blip can't flip the
        // comparison
        let (mut seq_host, mut seq_wall) = (f64::INFINITY, f64::INFINITY);
        let (mut bat_host, mut bat_wall) = (f64::INFINITY, f64::INFINITY);
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let mut host = 0.0;
            let mut seq_logits = Vec::with_capacity(burst);
            for p in &prompt_refs {
                let pre = b.prefill(p)?;
                host += pre.cost.host_waq_s;
                seq_logits.push(pre.logits);
            }
            seq_wall = seq_wall.min(t0.elapsed().as_secs_f64());
            seq_host = seq_host.min(host);

            let t0 = std::time::Instant::now();
            let pres = b.prefill_batch(&prompt_refs)?;
            bat_wall = bat_wall.min(t0.elapsed().as_secs_f64());
            bat_host = bat_host.min(pres.iter().map(|p| p.cost.host_waq_s).sum());
            // parity tripwire: the batched burst is bit-exact per request
            for (r, (want, pre)) in seq_logits.iter().zip(&pres).enumerate() {
                assert_eq!(
                    want, &pre.logits,
                    "batched prefill logits diverged from sequential (request {r})"
                );
            }
        }
        let speedup = seq_host / bat_host.max(1e-12);
        println!(
            "bench prefill_burst/{backend_name:15} burst={burst} plen={plen}  \
             seq-host {:.3} ms  batched-host {:.3} ms  speedup {speedup:.2}x",
            seq_host * 1e3,
            bat_host * 1e3,
        );
        if backend_name == "native-sharded" {
            // tripwire: one pool round per linear for the whole burst must
            // beat eight rounds' worth of dispatch/latch overhead
            assert!(
                bat_host < seq_host,
                "batched sharded prefill ({bat_host:.6}s host-WAQ) not faster than \
                 {burst} sequential prefills ({seq_host:.6}s)"
            );
        }
        for (mode, host, wall, speedup) in [
            ("sequential", seq_host, seq_wall, 1.0),
            ("batched", bat_host, bat_wall, speedup),
        ] {
            PrefillBenchRow {
                name: format!("prefill_burst/{backend_name}/{mode}"),
                backend: backend_name.to_string(),
                mode: mode.to_string(),
                burst: burst as u32,
                prompt_tokens,
                host_waq_s: host,
                wall_s: wall,
                tok_s: prompt_tokens as f64 / wall.max(1e-12),
                speedup_vs_sequential: speedup,
            }
            .append();
        }
    }
    Ok(())
}
