//! End-to-end serving bench: coordinator throughput/latency on the test
//! preset, decode-priority vs fill-all admission (the Fig 12-style batch
//! utilization story on the real runtime), and the software WAQ backend
//! comparison (direct vs histogram vs packed) as modeled host-datapath
//! seconds. Appends machine-readable results to BENCH_e2e.json.

use kllm::coordinator::{AdmitPolicy, Coordinator, EngineConfig};
use kllm::gemm::WaqBackend;
use kllm::runtime::{artifacts_dir, pjrt_available, Manifest, ParamSet};
use kllm::util::bench::{bench_json_path, fast_mode, BenchResult};
use kllm::util::rng::Rng;
use kllm::util::stats::LatencyStats;

fn main() -> anyhow::Result<()> {
    if !pjrt_available() {
        println!("kllm built without the `pjrt` feature — skipping e2e serving bench");
        return Ok(());
    }
    let dir = artifacts_dir("test");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/test missing — run `make artifacts`; skipping");
        return Ok(());
    }
    let manifest = Manifest::load(&dir).map_err(anyhow::Error::msg)?;
    let cfg = manifest.model;
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let n_requests = if fast_mode() { 6 } else { 24 };
    let max_new = 8;
    let json = bench_json_path("BENCH_e2e.json");

    let mut runs: Vec<(String, AdmitPolicy, WaqBackend)> = vec![
        (
            "decode-priority/packed".into(),
            AdmitPolicy::OnePerStep,
            WaqBackend::Packed,
        ),
        ("fill-all/packed".into(), AdmitPolicy::FillAll, WaqBackend::Packed),
    ];
    // backend sweep on the fill-all policy: the measured wall-clock is
    // PJRT-bound either way, but the modeled host-datapath seconds expose
    // the packed backend's decode advantage
    for backend in [WaqBackend::Direct, WaqBackend::Histogram] {
        runs.push((
            format!("fill-all/{}", backend.name()),
            AdmitPolicy::FillAll,
            backend,
        ));
    }

    for (name, policy, backend) in runs {
        let coord = Coordinator::start(
            "test".into(),
            ParamSet { tensors: params.tensors.clone() },
            EngineConfig { policy, waq_backend: backend, ..Default::default() },
        )?;
        let mut rng = Rng::new(3);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n_requests)
            .map(|_| {
                let prompt: Vec<i32> =
                    (0..4).map(|_| rng.below(cfg.vocab) as i32).collect();
                coord.submit_async(prompt, max_new, 0.0).unwrap().1
            })
            .collect();
        let mut lat = LatencyStats::default();
        let mut tokens = 0;
        for rx in rxs {
            let r = rx.recv()?;
            tokens += r.tokens.len();
            lat.record_us(r.total_s * 1e6);
        }
        let wall = t0.elapsed().as_secs_f64();
        let (stats, sim) = coord.stats()?;
        let summary = lat.summary();
        println!(
            "bench e2e_serving/{name:24} {:8.1} tok/s  occupancy {:.2}  {}  \
             modeled-OASIS {:.2} ms  modeled-host[{}] {:.2} ms",
            tokens as f64 / wall,
            stats.mean_occupancy(),
            summary,
            sim.seconds * 1e3,
            stats.waq_backend,
            stats.host_waq_s * 1e3,
        );
        // one JSON row of measured per-token wall clock (mean == p50 == min:
        // only the aggregate is observable here), and a separate row for the
        // modeled host-datapath per-token cost so the two trajectories stay
        // semantically distinct in BENCH_e2e.json
        let tok_ns = wall * 1e9 / (tokens.max(1) as f64);
        BenchResult {
            name: format!("e2e_serving/{name}"),
            iters: tokens as u64,
            mean_ns: tok_ns,
            p50_ns: tok_ns,
            min_ns: tok_ns,
            throughput: Some(tokens as f64 / wall),
        }
        .append_json(&json);
        let host_ns = stats.host_waq_s * 1e9 / (tokens.max(1) as f64);
        BenchResult {
            name: format!("e2e_serving/{name}/modeled-host"),
            iters: tokens as u64,
            mean_ns: host_ns,
            p50_ns: host_ns,
            min_ns: host_ns,
            throughput: None,
        }
        .append_json(&json);
        coord.shutdown()?;
    }
    Ok(())
}
