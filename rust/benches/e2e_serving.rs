//! End-to-end serving bench: coordinator throughput/latency on the test
//! preset, decode-priority vs fill-all admission (the Fig 12-style batch
//! utilization story on the real runtime).

use kllm::coordinator::{AdmitPolicy, Coordinator, EngineConfig};
use kllm::runtime::{artifacts_dir, Manifest, ParamSet};
use kllm::util::bench::fast_mode;
use kllm::util::rng::Rng;
use kllm::util::stats::LatencyStats;

fn main() -> anyhow::Result<()> {
    let dir = artifacts_dir("test");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/test missing — run `make artifacts`; skipping");
        return Ok(());
    }
    let manifest = Manifest::load(&dir).map_err(anyhow::Error::msg)?;
    let cfg = manifest.model;
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let n_requests = if fast_mode() { 6 } else { 24 };
    let max_new = 8;

    for (name, policy) in [
        ("decode-priority", AdmitPolicy::OnePerStep),
        ("fill-all", AdmitPolicy::FillAll),
    ] {
        let coord = Coordinator::start(
            "test".into(),
            ParamSet { tensors: params.tensors.clone() },
            EngineConfig { policy, ..Default::default() },
        )?;
        let mut rng = Rng::new(3);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..n_requests)
            .map(|_| {
                let prompt: Vec<i32> =
                    (0..4).map(|_| rng.below(cfg.vocab) as i32).collect();
                coord.submit_async(prompt, max_new, 0.0).unwrap().1
            })
            .collect();
        let mut lat = LatencyStats::default();
        let mut tokens = 0;
        for rx in rxs {
            let r = rx.recv()?;
            tokens += r.tokens.len();
            lat.record_us(r.total_s * 1e6);
        }
        let wall = t0.elapsed().as_secs_f64();
        let (stats, sim) = coord.stats()?;
        println!(
            "bench e2e_serving/{name:16} {:8.1} tok/s  occupancy {:.2}  {}  modeled-OASIS {:.2} ms",
            tokens as f64 / wall,
            stats.mean_occupancy(),
            lat.summary(),
            sim.seconds * 1e3,
        );
        coord.shutdown()?;
    }
    Ok(())
}
