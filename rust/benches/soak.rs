//! Serving-robustness soak bench: a heavy-tailed multi-client trace
//! through the in-process coordinator API and the TCP JSON-lines
//! front-end, with chaos fault injection enabled and a bounded admission
//! queue. Tripwires (any failure fails the run, and CI): every submitted
//! request resolves to exactly one terminal response, nothing hangs, and
//! the final graceful drain returns every KV block. Rows land in
//! BENCH_soak.json via `util::bench::SoakBenchRow` — accepted/rejected/
//! expired/aborted counts, p50/p99 admission wait, drain time — so the
//! robustness envelope is tracked across PRs. CI smoke-runs this under
//! FAST_BENCH=1 with a shrunk trace.

use std::sync::Arc;
use std::time::Duration;

use kllm::coordinator::{
    AdmitPolicy, BackendSpec, ChaosCfg, Coordinator, EngineConfig, FinishReason, TcpCfg,
};
use kllm::gemm::WaqBackend;
use kllm::kvcache::KvBits;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::util::bench::{fast_mode, SoakBenchRow};
use kllm::util::json::Json;
use kllm::util::rng::Rng;
use kllm::util::stats::percentile_sorted;

const CHAOS_SEED: u64 = 0xC4A05;
const CHAOS_RATE: f64 = 0.02;

fn soak_cfg() -> ModelCfg {
    ModelCfg { decode_batch: 4, ..ModelCfg::test_preset() }
}

fn start_coordinator(cfg: ModelCfg) -> anyhow::Result<Coordinator> {
    let manifest = Manifest::synthetic("test", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    Coordinator::start_with_manifest(
        manifest,
        params,
        EngineConfig {
            backend: BackendSpec::Native(WaqBackend::Packed),
            policy: AdmitPolicy::FillAll,
            kv_bits: KvBits::B4,
            queue_cap: 16,
            chaos: Some(ChaosCfg::uniform(CHAOS_SEED, CHAOS_RATE)),
            ..Default::default()
        },
    )
}

/// Heavy-tailed per-request shape: mostly short prompts/generations with
/// an occasional long one (the tail is what stresses admission + drain).
fn trace_request(rng: &mut Rng, vocab: usize, seq_len: usize) -> (Vec<i32>, usize) {
    let mag = rng.heavy_tailed(0.1, 6.0).abs() as usize;
    let plen = (1 + rng.below(4) + mag).min(seq_len - 1);
    let prompt = (0..plen).map(|_| rng.below(vocab) as i32).collect();
    let max_new = 1 + rng.below(4) + mag / 2;
    (prompt, max_new)
}

/// Terminal-outcome tally for one soak phase.
#[derive(Default)]
struct Tally {
    completed: u64,
    rejected: u64,
    expired: u64,
    aborted: u64,
    queue_waits: Vec<f64>,
}

impl Tally {
    fn record(&mut self, reason: FinishReason, queue_wait_s: f64) {
        match reason {
            FinishReason::Rejected => self.rejected += 1,
            FinishReason::DeadlineExpired => self.expired += 1,
            FinishReason::Aborted => self.aborted += 1,
            _ => self.completed += 1,
        }
        self.queue_waits.push(queue_wait_s);
    }

    fn total(&self) -> u64 {
        self.completed + self.rejected + self.expired + self.aborted
    }

    fn percentiles(&mut self) -> (f64, f64) {
        self.queue_waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
        (
            percentile_sorted(&self.queue_waits, 50.0),
            percentile_sorted(&self.queue_waits, 99.0),
        )
    }
}

fn emit(name: &str, mut tally: Tally, requests: u64, drain_s: f64) {
    assert_eq!(
        tally.total(),
        requests,
        "{name}: every request must resolve to exactly one terminal response"
    );
    let (p50, p99) = tally.percentiles();
    let row = SoakBenchRow {
        name: name.to_string(),
        backend: "native-packed".to_string(),
        requests,
        completed: tally.completed,
        rejected: tally.rejected,
        expired: tally.expired,
        aborted: tally.aborted,
        p50_queue_wait_s: p50,
        p99_queue_wait_s: p99,
        drain_s,
        chaos_rate: CHAOS_RATE,
        chaos_seed: CHAOS_SEED,
    };
    println!(
        "bench {name:32} {requests:5} req  done {:5}  rej {:3}  exp {:3}  abort {:3}  \
         p50 wait {:8.1} us  p99 wait {:8.1} us  drain {:.3} s",
        row.completed,
        row.rejected,
        row.expired,
        row.aborted,
        1e6 * row.p50_queue_wait_s,
        1e6 * row.p99_queue_wait_s,
        row.drain_s,
    );
    row.append();
}

/// Phase 1: multi-client trace through the in-process API, ending with a
/// last wave deliberately left in flight when the graceful drain starts —
/// those requests must come back finished, aborted, or rejected, never
/// hang.
fn inproc_phase(clients: u64, per_client: u64) -> anyhow::Result<()> {
    let cfg = soak_cfg();
    let coord = Arc::new(start_coordinator(cfg)?);
    let mut tally = Tally::default();
    let mut handles = Vec::new();
    for c in 0..clients {
        let coord = coord.clone();
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<(FinishReason, f64)>> {
            let mut rng = Rng::new(0x50AC ^ c);
            let mut out = Vec::new();
            for i in 0..per_client {
                let (prompt, max_new) = trace_request(&mut rng, cfg.vocab, cfg.seq_len);
                // a slice of the trace carries deadlines: already-expired
                // (must expire) or far-future (must not interfere)
                let deadline = match (c + i) % 8 {
                    0 => Some(0),
                    1 => Some(3_600_000),
                    _ => None,
                };
                let (_, rx) = coord.submit_with(prompt, max_new, 0.0, deadline)?;
                let resp = rx.recv_timeout(Duration::from_secs(60))?;
                out.push((resp.finish_reason, resp.queue_wait_s));
            }
            Ok(out)
        }));
    }
    for h in handles {
        for (reason, wait) in h.join().expect("client thread")? {
            tally.record(reason, wait);
        }
    }
    // last wave: submitted but NOT received before drain begins
    let mut rng = Rng::new(0xD12A1);
    let wave = clients * 2;
    let mut pending = Vec::new();
    for _ in 0..wave {
        let (prompt, max_new) = trace_request(&mut rng, cfg.vocab, cfg.seq_len);
        let (_, rx) = coord.submit_with(prompt, max_new, 0.0, None)?;
        pending.push(rx);
    }
    let report = coord.drain(Duration::from_secs(30))?;
    for rx in pending {
        let resp = rx
            .recv_timeout(Duration::from_secs(5))
            .expect("drain must answer every in-flight request");
        tally.record(resp.finish_reason, resp.queue_wait_s);
    }
    assert_eq!(report.in_use_blocks, 0, "drain leaked KV blocks");
    emit(
        "soak/native-packed/inproc",
        tally,
        clients * per_client + wave,
        report.drain_s,
    );
    Ok(())
}

/// Phase 2: the same trace shape through the TCP JSON-lines front-end —
/// exactly one parseable reply per request line, then a graceful drain.
fn tcp_phase(clients: u64, per_client: u64) -> anyhow::Result<()> {
    use std::io::{BufRead, BufReader, Write};
    let cfg = soak_cfg();
    let coord = Arc::new(start_coordinator(cfg)?);
    let tcp = TcpCfg { max_conns: 64, read_timeout: Some(Duration::from_secs(60)) };
    let port = kllm::coordinator::serve_tcp_with(coord.clone(), 0, tcp)?;
    let mut tally = Tally::default();
    let mut handles = Vec::new();
    for c in 0..clients {
        handles.push(std::thread::spawn(move || -> anyhow::Result<Vec<(FinishReason, f64)>> {
            let mut rng = Rng::new(0x7C9 ^ c);
            let mut sock = std::net::TcpStream::connect(("127.0.0.1", port))?;
            let mut reader = BufReader::new(sock.try_clone()?);
            let mut out = Vec::new();
            for i in 0..per_client {
                let (prompt, max_new) = trace_request(&mut rng, cfg.vocab, cfg.seq_len);
                let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
                let deadline = if (c + i) % 8 == 0 { ", \"deadline_ms\": 0" } else { "" };
                let line = format!(
                    "{{\"prompt\": [{}], \"max_new_tokens\": {max_new}{deadline}}}\n",
                    toks.join(",")
                );
                sock.write_all(line.as_bytes())?;
                let mut reply = String::new();
                reader.read_line(&mut reply)?;
                let j = Json::parse(reply.trim())
                    .map_err(|e| anyhow::anyhow!("unparseable reply {reply:?}: {e}"))?;
                let reason = match j.get("finish_reason").and_then(Json::as_str) {
                    Some("rejected") => FinishReason::Rejected,
                    Some("deadline_expired") => FinishReason::DeadlineExpired,
                    Some("aborted") => FinishReason::Aborted,
                    Some(_) => FinishReason::MaxTokens,
                    None => anyhow::bail!("reply without finish_reason: {reply:?}"),
                };
                let wait = j.get("queue_wait_s").and_then(Json::as_f64).unwrap_or(0.0);
                out.push((reason, wait));
            }
            Ok(out)
        }));
    }
    for h in handles {
        for (reason, wait) in h.join().expect("tcp client thread")? {
            tally.record(reason, wait);
        }
    }
    let report = coord.drain(Duration::from_secs(30))?;
    assert_eq!(report.in_use_blocks, 0, "drain leaked KV blocks");
    emit(
        "soak/native-packed/tcp",
        tally,
        clients * per_client,
        report.drain_s,
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let (clients, per_client) = if fast_mode() { (3, 8) } else { (8, 40) };
    inproc_phase(clients, per_client)?;
    tcp_phase(clients, per_client)?;
    Ok(())
}
