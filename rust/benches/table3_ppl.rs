//! Bench for Table III: the quantized-eval pipeline (calibration + weight
//! quantization + PPL eval through the artifacts). Uses the `test` preset
//! with a short training run; the full table is `kllm experiment table3`.

use kllm::eval::methods::Method;
use kllm::eval::ppl::{eval_method, eval_nll, ppl, train_or_load};
use kllm::eval::{calibrate, Corpus};
use kllm::quant::OutlierCfg;
use kllm::runtime::{artifacts_dir, pjrt_available, Runtime};
use kllm::util::bench::fast_mode;

fn main() -> anyhow::Result<()> {
    if !pjrt_available() {
        println!("kllm built without the `pjrt` feature — skipping table3 bench");
        return Ok(());
    }
    let dir = artifacts_dir("test");
    if !dir.join("manifest.json").exists() {
        println!("artifacts/test missing — run `make artifacts`; skipping");
        return Ok(());
    }
    let steps = if fast_mode() { 60 } else { 200 };
    let mut rt = Runtime::new(&dir)?;
    let t0 = std::time::Instant::now();
    let (params, _) = train_or_load(&mut rt, Corpus::Wiki2, steps, 3e-3, 0x7121)?;
    println!("train_or_load({steps} steps): {:.1}s", t0.elapsed().as_secs_f64());

    let t0 = std::time::Instant::now();
    let calib = calibrate(&mut rt, &params, Corpus::C4, 16, OutlierCfg::default())?;
    println!("calibration (16 samples): {:.2}s", t0.elapsed().as_secs_f64());

    let fp = ppl(eval_nll(&mut rt, None, &params, &[], Corpus::Wiki2, 4, 0xE7A1)?);
    println!("{:18} PPL {fp:.3}", "FP32");
    for method in Method::ALL_QUANT {
        let t0 = std::time::Instant::now();
        let (p, qs) = eval_method(&mut rt, &params, &calib, method, 4, Corpus::Wiki2, 4)?;
        println!(
            "{:18} PPL {p:.3} (dPPL {:+.3})  quant {qs:.2}s  eval {:.2}s",
            method.label(),
            p - fp,
            t0.elapsed().as_secs_f64() - qs
        );
    }
    Ok(())
}
