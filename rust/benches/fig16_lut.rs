//! Bench for Fig 16: LUT sizes and reduction FLOPs across LLaMA scales,
//! plus measured LUT build/regeneration costs (the WOQ schemes pay
//! per-token regeneration; OASIS builds once offline).

use kllm::baselines::fig16_costs;
use kllm::gemm::CartesianLut;
use kllm::models::by_name;
use kllm::quant::Codebook;
use kllm::util::bench::{black_box, Bencher};
use kllm::util::rng::Rng;

fn main() {
    println!("== Fig 16 bench ==");
    for name in ["LLaMA-7B", "LLaMA-13B", "LLaMA-30B", "LLaMA-2-70B"] {
        let m = by_name(name).unwrap();
        let d = m.d_model;
        for c in fig16_costs(d, d) {
            println!(
                "{name:12} {:16} lut_entries={:>9} reduction_flops={:>12}",
                c.name, c.lut_entries, c.reduction_flops
            );
        }
    }
    let mut rng = Rng::new(2);
    let cb_a = Codebook::new(rng.normal_vec(16, 1.0));
    let cb_w = Codebook::new(rng.normal_vec(16, 1.0));
    let b = Bencher::quick();
    b.run("cartesian LUT build (offline, once)", || {
        black_box(CartesianLut::build(&cb_a, &cb_w));
    });
    // WOQ regenerates group LUTs per token: emulate one 4096-length token
    let x = rng.normal_vec(4096, 1.0);
    let w_q = vec![1i8; 4096 * 4];
    b.run("woq per-token LUT gen + gemv (K=4096, N=4)", || {
        black_box(kllm::gemm::woq::woq_lut_gemv(&x, &w_q, 4, 4, 4));
    });
}
