//! Bench for Figs 11-13: simulated decode/prefill throughput + energy of
//! OASIS vs A100 / QuaRot / FIGLUT across the model zoo.

use kllm::baselines::{a100_fp16, figlut, quarot_w4a4};
use kllm::models::ZOO;
use kllm::sim::{self, HwConfig, OasisMode};
use kllm::util::bench::{black_box, fast_mode, Bencher};
use kllm::util::stats::geomean;

fn main() {
    let hw = HwConfig::default();
    let out_len = if fast_mode() { 128 } else { 2048 };
    println!("== Fig 11 bench: single-batch decode, out_len {out_len} ==");
    let mut sp_f = Vec::new();
    for m in ZOO {
        let f = figlut().generation_cost(m, 1, 0, out_len);
        let a4 = sim::generation_cost(&hw, m, OasisMode::a4(), 1, 0, out_len);
        let gpu = a100_fp16();
        let qr = quarot_w4a4().generation_cost(m, 1, 0, out_len);
        sp_f.push(f.seconds / a4.seconds);
        println!(
            "{:12} OASIS-A4 {:8.1} tok/s | FIGLUT {:8.1} | QuaRot {:8.1} | A100 {}",
            m.name,
            out_len as f64 / a4.seconds,
            out_len as f64 / f.seconds,
            out_len as f64 / qr.seconds,
            if gpu.fits(m) {
                format!("{:8.1}", out_len as f64 / gpu.generation_cost(m, 1, 0, out_len).seconds)
            } else {
                "OOM".into()
            }
        );
    }
    println!("avg OASIS-A4 / FIGLUT speedup: {:.2}x (paper 3.00x)", geomean(&sp_f));

    // Fig 12 slice: batch scaling
    println!("\n== Fig 12 slice: LLaMA-2-7B batch scaling ==");
    let m = kllm::models::by_name("LLaMA-2-7B").unwrap();
    for batch in [1usize, 2, 4] {
        let a4 = sim::generation_cost(&hw, m, OasisMode::a4(), batch, 0, 256);
        println!(
            "batch {batch}: OASIS-A4 {:.1} tok/s, {:.2} J",
            (256 * batch) as f64 / a4.seconds,
            a4.energy_j
        );
    }

    // the simulator itself is on the coordinator's hot path: bench it
    let b = Bencher::default();
    b.run("sim decode_step_cost (LLaMA-2-7B)", || {
        black_box(sim::decode_step_cost(&hw, m, OasisMode::a4(), 1, 1024));
    });
}
