//! Orizuru bench: comparison counts + wallclock vs sort/heap baselines,
//! across the paper's relevant N (hidden sizes) and k (outlier counts).

use kllm::orizuru::{baseline, Orizuru};
use kllm::util::bench::{black_box, fast_mode, Bencher};
use kllm::util::rng::Rng;

fn main() {
    println!("== Orizuru bench ==");
    let sizes: &[(usize, usize)] = if fast_mode() {
        &[(1024, 10)]
    } else {
        &[(2048, 10), (4096, 20), (11008, 55)]
    };
    let mut rng = Rng::new(1);
    for &(n, k) in sizes {
        let x = rng.heavy_tailed_vec(n, 0.01, 15.0);
        let mut o = Orizuru::new(&x);
        o.top_k(k);
        let (_, _, heap_cmp) = baseline::HeapTopK::run(&x, k);
        let (_, _, sort_cmp) = baseline::sort_topk(&x, k);
        println!(
            "n={n:>6} k={k:>3}: orizuru {} cmps (model {:.0}) | spatten-6N {} | heap {} | sort {}",
            o.comparisons(),
            Orizuru::paper_cost_model(n, k),
            baseline::spatten_cost_model(n) as u64,
            heap_cmp,
            sort_cmp
        );
        let b = Bencher::default().throughput(n as u64);
        b.run(&format!("orizuru n={n} k={k}"), || {
            let mut o = Orizuru::new(black_box(&x));
            black_box(o.top_k(k));
        });
        b.run(&format!("sort    n={n} k={k}"), || {
            black_box(baseline::sort_topk(&x, k));
        });
        b.run(&format!("heap    n={n} k={k}"), || {
            black_box(baseline::HeapTopK::run(&x, k));
        });
    }
}
