//! Prefix-cache bench: a fleet of requests sharing one long system
//! prompt (the workload the radix index exists for), served twice — with
//! `--prefix-cache off` (every prompt densely prefilled) and on (shared
//! blocks aliased out of the index; only each request's private tail is
//! computed). Rows land in BENCH_prefix.json via
//! `util::bench::PrefixBenchRow`. Requests use `max_new_tokens = 1`, so
//! the first token comes straight from the prefill logits and the host
//! WAQ seconds isolate prefill cost.
//!
//! Tripwires (non-zero exit, so CI fails when the subsystem regresses):
//!   * hit rate: every admission after the first cold burst must hit the
//!     index (`prefix_hits >= requests - decode_batch`);
//!   * payoff: host seconds off/on must be >= 10x on the full workload
//!     (100 requests x 1k-token shared head), >= 1.5x under FAST_BENCH
//!     (12 requests x 48-token head — the cold burst amortizes less).

use kllm::coordinator::{
    AdmitPolicy, BackendSpec, Engine, EngineConfig, NativeCfg, NativeWaqBackend, Request,
};
use kllm::gemm::WaqBackend;
use kllm::kvcache::KvBits;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::util::bench::{fast_mode, PrefixBenchRow};
use kllm::util::rng::Rng;

struct Workload {
    name: &'static str,
    requests: u64,
    shared_tokens: usize,
    min_speedup: f64,
}

/// One full serve of the shared-prefix stream; returns the engine for
/// stats inspection.
fn serve(
    cfg: ModelCfg,
    manifest: &Manifest,
    params: &ParamSet,
    kv_bits: KvBits,
    prefix_cache: bool,
    w: &Workload,
) -> anyhow::Result<Engine> {
    let backend = NativeWaqBackend::new(
        manifest,
        params,
        NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() },
    )?;
    let ecfg = EngineConfig {
        policy: AdmitPolicy::FillAll,
        backend: BackendSpec::Native(WaqBackend::Packed),
        kv_bits,
        prefix_cache,
        ..Default::default()
    };
    let mut engine = Engine::new(Box::new(backend), &ecfg);
    let mut rng = Rng::new(11);
    let head: Vec<i32> =
        (0..w.shared_tokens).map(|_| rng.below(cfg.vocab) as i32).collect();
    for id in 0..w.requests {
        // shared head + an 8-token private tail (distinct per request, so
        // tails never alias and COW fires on the final partial block)
        let mut prompt = head.clone();
        prompt.extend((0..8).map(|t| ((id as usize * 31 + t * 7 + 1) % cfg.vocab) as i32));
        engine.submit(Request::new(id, prompt, 1));
    }
    engine.run_to_completion()?;
    Ok(engine)
}

fn main() -> anyhow::Result<()> {
    let w = if fast_mode() {
        Workload { name: "fast", requests: 12, shared_tokens: 48, min_speedup: 1.5 }
    } else {
        Workload { name: "full", requests: 100, shared_tokens: 1024, min_speedup: 10.0 }
    };
    // context: shared head + 8-token tail + 1 generated, rounded up to a
    // block boundary so the bench shape never depends on seq_len slack
    let seq_len = (w.shared_tokens + 16).next_multiple_of(16);
    let cfg = ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        seq_len,
        batch: 1,
        decode_batch: 2,
        head_dim: 16,
        d_ff: 128,
        n_linears: 8,
    };
    let manifest = Manifest::synthetic("prefix-bench", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));

    let mut failures = Vec::new();
    for kv_bits in [KvBits::Fp32, KvBits::B4] {
        let off = serve(cfg, &manifest, &params, kv_bits, false, &w)?;
        let on = serve(cfg, &manifest, &params, kv_bits, true, &w)?;
        assert_eq!(
            off.stats.completed, on.stats.completed,
            "both runs must complete the full stream"
        );
        let speedup = off.stats.host_waq_s / on.stats.host_waq_s.max(1e-12);
        let row = PrefixBenchRow {
            name: format!("prefix/{}", w.name),
            backend: on.stats.waq_backend.to_string(),
            kv_bits: on.stats.kv_bits,
            requests: w.requests,
            shared_tokens: w.shared_tokens as u64,
            host_s_off: off.stats.host_waq_s,
            host_s_on: on.stats.host_waq_s,
            speedup,
            prefix_hits: on.stats.prefix_hits,
            blocks_reused: on.stats.prefix_blocks_reused,
            evictions: on.stats.evictions,
            bytes_per_token: on.stats.kv_bytes_per_token,
        };
        println!(
            "bench prefix_cache/{}/kv{:<2} off {:.4}s  on {:.4}s  {:5.1}x  \
             hits {}/{}  reused {}  evicted {}",
            w.name,
            row.kv_bits,
            row.host_s_off,
            row.host_s_on,
            row.speedup,
            row.prefix_hits,
            w.requests,
            row.blocks_reused,
            row.evictions,
        );
        row.append();

        // tripwire: everything after the cold first burst must hit
        let min_hits = w.requests - cfg.decode_batch as u64;
        if row.prefix_hits < min_hits {
            failures.push(format!(
                "kv{}: prefix_hits {} < {} (requests {} - decode_batch {})",
                row.kv_bits, row.prefix_hits, min_hits, w.requests, cfg.decode_batch
            ));
        }
        // tripwire: the cache must actually buy prefill host time back
        if speedup < w.min_speedup {
            failures.push(format!(
                "kv{}: off/on host speedup {:.2}x < {:.1}x floor",
                row.kv_bits, speedup, w.min_speedup
            ));
        }
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("prefix_cache tripwire: {f}");
        }
        anyhow::bail!("{} prefix_cache tripwire(s) fired", failures.len());
    }
    Ok(())
}
