//! Bench for Fig 14 + Fig 18: pipeline schedule and traffic/energy
//! breakdown of the 1-4096-4096 GEMM, and the cost-model throughput.

use kllm::sim::{self, energy, pipeline, HwConfig};
use kllm::util::bench::{black_box, Bencher};

fn main() {
    let hw = HwConfig::default();
    let s = pipeline::schedule(&hw, 1, 4096, 4096, 4, 0.01);
    println!("== Fig 14: 1-4096-4096 W4A4, 1% outliers ==");
    for st in &s.steps {
        println!(
            "{:8} {:14} start {:>6} cycles {:>6}{}",
            st.branch,
            st.name,
            st.start,
            st.cycles,
            if st.bottleneck { "  <-- bottleneck" } else { "" }
        );
    }
    println!(
        "main {} / outlier {} / total {} cycles ({:.1} us at 500 MHz)",
        s.main_end,
        s.outlier_end,
        s.total,
        s.total as f64 * 2e-3
    );

    let c = sim::gemm_cost(&hw, 1, 4096, 4096, 4, 0.01);
    let t = energy::gemm_traffic(&hw, &c, 4);
    let e = energy::gemm_energy(&hw, &c, 4);
    println!("\n== Fig 18(a): traffic breakdown ==");
    for (k, v) in &t.by_component {
        println!("{k:16} {:>12.0} B  {:5.1}%", v, t.fraction(k) * 100.0);
    }
    println!("== Fig 18(b): energy breakdown ==");
    for (k, v) in &e.by_component {
        println!("{k:16} {:>9.2} uJ  {:5.1}%", v * 1e6, e.fraction(k) * 100.0);
    }

    let b = Bencher::default();
    b.run("gemm_cost model (4096x4096)", || {
        black_box(sim::gemm_cost(&hw, 1, 4096, 4096, 4, 0.01));
    });
    b.run("pipeline schedule", || {
        black_box(pipeline::schedule(&hw, 1, 4096, 4096, 4, 0.01));
    });
}
