//! Bench for Table I: WAQ LUT-GEMM vs WOQ LUT-GEMM — analytic scheme
//! comparison plus measured software-path timings at the paper's shapes.

use kllm::gemm::{self, lut::analytics, CartesianLut};
use kllm::quant::{self, OutlierCfg};
use kllm::tensor::Matrix;
use kllm::util::bench::{black_box, fast_mode, Bencher};
use kllm::util::rng::Rng;

fn main() {
    let (k, n) = if fast_mode() { (512, 512) } else { (4096, 1024) };
    println!("== Table I bench: M=1, K={k}, N={n} ==");
    println!(
        "analytic: WOQ lut {} entries / {} flops; WAQ lut {} entries / {} flops",
        analytics::woq_lut_entries(k, 4),
        analytics::woq_reduction_flops(k, 4, 4, n),
        analytics::waq_lut_entries(4, 4),
        analytics::waq_reduction_flops(4, 4, n)
    );

    let mut rng = Rng::new(1);
    let w = Matrix::random_normal(k, n, 1.0, &mut rng);
    let qw = quant::quantize_weights(&w, 4);
    let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.normal_vec(k, 1.0)).collect();
    let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
    let cb_a = quant::learn_act_codebook(&refs, None, 4, OutlierCfg::default());
    let x = rng.normal_vec(k, 1.0);
    let tok = quant::quantize_token(&x, &cb_a, OutlierCfg::default());
    let lut = CartesianLut::build(&cb_a, &qw.codebook);
    let w_q: Vec<i8> = qw
        .idx
        .iter()
        .map(|&q| (q as i32 - 8) as i8)
        .collect();

    let pw = qw.pack();
    let b = Bencher::default().throughput((k * n) as u64).json("BENCH_waq_gemm.json");
    b.run("waq_lut_gemm (direct)", || {
        black_box(gemm::execute_direct(&tok, &qw, &lut));
    });
    b.run("waq_lut_gemm (packed fused pair-LUT)", || {
        black_box(gemm::execute_packed(&tok, &pw, &lut));
    });
    b.run("waq_lut_gemm (histogram/hw)", || {
        black_box(gemm::execute_histogram(&tok, &qw, &lut));
    });
    b.run("waq dual-branch (with compensation)", || {
        black_box(gemm::execute_dual_branch(&tok, &qw, &lut));
    });
    b.run("woq_lut_gemm (bit-serial, mu=4)", || {
        black_box(gemm::woq::woq_lut_gemv(&x, &w_q, n, 4, 4));
    });
    let xm = Matrix::from_vec(1, k, x.clone());
    let wd = qw.dequantize();
    b.run("dequant + f32 gemm (Fig 1(c) path)", || {
        black_box(xm.matmul(&wd));
    });
}
