//! Speculative-decoding bench: the same request stream served by the
//! packed target alone and by `--backend native-spec` across
//! `(--spec-k, --draft-wbits)` settings. Rows land in BENCH_spec.json via
//! `util::bench::SpecBenchRow`.
//!
//! Two measurement planes, deliberately separated:
//!
//!   * **acceptance is measured, not assumed** — the engine serves the
//!     test preset on the real native WAQ datapath and the rows report
//!     the observed `spec_accepted / spec_proposed`. A *random-init*
//!     model has a near-uniform next-token distribution (greedy argmax
//!     gaps of fractions of a percent), so draft/target agreement is
//!     chance — a regime no speculative system can serve. The bench
//!     instead builds a *predictable* synthetic model: `ParamSet::init`
//!     with each layer's residual contributions (`attn_out`, `mlp_down`)
//!     damped 50x, giving the peaked, easy-token behavior trained models
//!     show — the workload speculative decoding exists for.
//!   * **the payoff is priced at the bandwidth roofline** — a stacked
//!     verify still executes k+1 LUT-GEMM rows of real compute, so
//!     neither host wall-clock nor the compute-balanced Table II cycle
//!     model (whose PE array is sized to its HBM, leaving no slack for
//!     extra rows) can beat the target alone; the rows publish measured
//!     `host_tok_s` anyway. The win lives where serving-class decode
//!     actually runs: weight-bandwidth-bound, the regime the KLLM paper
//!     (and this repo's `sim::llm` decode model) is built around. The
//!     `tok_s_bw` projection prices the measured round shape in HBM
//!     bytes at LLaMA-2-7B scale — k_eff draft steps streaming
//!     `draft_wbits`-bit weights, ONE target weight stream for the whole
//!     k+1-row verify, per-row KV traffic, `accept + 1` tokens out.
//!
//! Tripwires (non-zero exit, so CI fails when the subsystem regresses):
//!   * bit-exactness: every speculative config must reproduce the
//!     target-alone token streams exactly (greedy parity, per request);
//!   * acceptance: the predictable workload must accept >= 50% of
//!     proposals at every setting (the design estimate is ~95%; a
//!     collapse here means draft/target drift);
//!   * payoff: the best config's `tok_s_bw` must be >= the target-alone
//!     roofline (speculative >= target on the test preset).

use std::collections::HashMap;

use kllm::coordinator::{
    AdmitPolicy, BackendSpec, Engine, EngineConfig, NativeCfg, NativeWaqBackend, Request,
    SpeculativeBackend,
};
use kllm::gemm::WaqBackend;
use kllm::models::by_name;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::sim::{HwConfig, OasisMode};
use kllm::util::bench::{fast_mode, SpecBenchRow};
use kllm::util::rng::Rng;

/// Residual damping for the predictable synthetic model: scales each
/// layer's `attn_out` / `mlp_down` so the residual stream is dominated by
/// the embedding path and the greedy argmax develops real margins.
const RESIDUAL_DAMP: f32 = 0.02;

/// Context length for the roofline's KV-traffic term.
const PROJ_CTX: usize = 1024;

struct Workload {
    name: &'static str,
    requests: u64,
    max_new: usize,
    configs: &'static [(usize, u32)],
}

fn requests_for(cfg: &ModelCfg, w: &Workload) -> Vec<Request> {
    (0..w.requests)
        .map(|id| {
            let prompt: Vec<i32> = (0..10)
                .map(|t| ((id as usize * 37 + t * 13 + 5) % cfg.vocab) as i32)
                .collect();
            Request::new(id, prompt, w.max_new)
        })
        .collect()
}

/// Serve the workload; returns (engine, tokens by request id).
fn serve(
    manifest: &Manifest,
    params: &ParamSet,
    spec: Option<(usize, u32)>,
    w: &Workload,
) -> anyhow::Result<(Engine, HashMap<u64, Vec<i32>>)> {
    let ncfg = NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() };
    let target = NativeWaqBackend::new(manifest, params, ncfg)?;
    let mut ecfg = EngineConfig {
        policy: AdmitPolicy::FillAll,
        backend: BackendSpec::Native(WaqBackend::Packed),
        ..Default::default()
    };
    let backend: Box<dyn kllm::coordinator::DecodeBackend> = match spec {
        None => Box::new(target),
        Some((k, wbits)) => {
            ecfg.backend = BackendSpec::NativeSpec;
            ecfg.spec_k = k;
            ecfg.draft_wbits = wbits;
            Box::new(SpeculativeBackend::new(
                manifest,
                params,
                Box::new(target),
                ecfg.mode,
                k,
                wbits,
            )?)
        }
    };
    let mut engine = Engine::new(backend, &ecfg);
    for req in requests_for(&manifest.model, w) {
        engine.submit(req);
    }
    let responses = engine.run_to_completion()?;
    let mut tokens = HashMap::new();
    for r in responses {
        tokens.insert(r.id, r.tokens);
    }
    Ok((engine, tokens))
}

/// HBM bytes of one decode/verify weight stream at LLaMA-2-7B scale with
/// `wbits`-bit weight indices (linears + LM head), and the 4-bit KV-cache
/// bytes one row reads at [`PROJ_CTX`].
fn roofline_bytes_7b() -> (f64, f64) {
    let m = by_name("LLaMA-2-7B").expect("7B spec");
    let wgt4 = (m.linear_params() + m.d_model * m.vocab) as f64 * 0.5;
    let kv_row = m.kv_bytes_per_token(OasisMode::a4().kv_bytes_per_elem()) * PROJ_CTX as f64;
    (wgt4, kv_row)
}

fn main() -> anyhow::Result<()> {
    let w = if fast_mode() {
        Workload { name: "fast", requests: 4, max_new: 8, configs: &[(1, 2), (4, 2)] }
    } else {
        Workload {
            name: "full",
            requests: 8,
            max_new: 16,
            configs: &[(1, 2), (2, 2), (4, 2), (2, 3), (4, 3)],
        }
    };
    let cfg = ModelCfg::test_preset();
    let manifest = Manifest::synthetic("spec-bench", cfg);
    let mut params = ParamSet::init(&manifest, &mut Rng::new(42));
    for l in 0..cfg.n_layers {
        for name in [format!("l{l}.attn_out"), format!("l{l}.mlp_down")] {
            let idx = ParamSet::index_of(&manifest, &name).expect("manifest param");
            let mut m = params.matrix(idx)?;
            for v in m.data.iter_mut() {
                *v *= RESIDUAL_DAMP;
            }
            params.set_matrix(idx, &m)?;
        }
    }

    let bw = HwConfig::default().hbm_bytes_per_sec;
    let (wgt4, kv_row) = roofline_bytes_7b();
    let target_tok_s_bw = bw / (wgt4 + kv_row);
    let (target, target_tokens) = serve(&manifest, &params, None, &w)?;
    let trow = SpecBenchRow {
        name: format!("spec/{}/target", w.name),
        backend: target.stats.waq_backend.to_string(),
        spec_k: 0,
        draft_wbits: 0,
        requests: w.requests,
        generated_tokens: target.stats.generated_tokens,
        spec_rounds: 0,
        proposed: 0,
        accepted: 0,
        accept_rate: 0.0,
        host_waq_s: target.stats.host_waq_s,
        host_tok_s: target.stats.generated_tokens as f64
            / target.stats.host_waq_s.max(1e-12),
        tok_s_bw: target_tok_s_bw,
        speedup_bw: 1.0,
    };
    println!(
        "bench spec_decode/{}/target          host {:8.1} tok/s  bw {:8.1} tok/s",
        w.name, trow.host_tok_s, trow.tok_s_bw
    );
    trow.append();

    let mut failures = Vec::new();
    let mut best_bw = 0.0f64;
    for &(k, wbits) in w.configs {
        let (engine, tokens) = serve(&manifest, &params, Some((k, wbits)), &w)?;
        let s = &engine.stats;
        if s.step_failures > 0 || s.prefill_failures > 0 {
            failures.push(format!(
                "k{k}w{wbits}: {} step / {} prefill failures",
                s.step_failures, s.prefill_failures
            ));
        }
        if tokens != target_tokens {
            failures.push(format!(
                "k{k}w{wbits}: speculative token streams diverge from the target's"
            ));
        }
        let accept_rate = s.spec_accepted as f64 / s.spec_proposed.max(1) as f64;
        // measured round shape -> roofline: k_eff draft steps streaming
        // wbits-bit weights + their KV row, one 4-bit target weight
        // stream for the whole stacked verify + k+1 KV rows, accept+1
        // tokens emitted per round
        let rounds = s.spec_rounds.max(1) as f64;
        let k_eff = s.spec_proposed as f64 / rounds;
        let acc_mean = s.spec_accepted as f64 / rounds;
        let round_bytes = k_eff * (wgt4 * wbits as f64 / 4.0 + kv_row)
            + wgt4
            + (k as f64 + 1.0) * kv_row;
        let tok_s_bw = bw / (round_bytes / (acc_mean + 1.0));
        best_bw = best_bw.max(tok_s_bw);
        let row = SpecBenchRow {
            name: format!("spec/{}/k{k}w{wbits}", w.name),
            backend: s.waq_backend.to_string(),
            spec_k: k as u32,
            draft_wbits: wbits,
            requests: w.requests,
            generated_tokens: s.generated_tokens,
            spec_rounds: s.spec_rounds,
            proposed: s.spec_proposed,
            accepted: s.spec_accepted,
            accept_rate,
            host_waq_s: s.host_waq_s,
            host_tok_s: s.generated_tokens as f64 / s.host_waq_s.max(1e-12),
            tok_s_bw,
            speedup_bw: tok_s_bw / target_tok_s_bw,
        };
        println!(
            "bench spec_decode/{}/k{k}w{wbits}  accept {:5.1}%  host {:8.1} tok/s  \
             bw {:8.1} tok/s  {:4.2}x",
            w.name,
            100.0 * row.accept_rate,
            row.host_tok_s,
            row.tok_s_bw,
            row.speedup_bw,
        );
        row.append();

        if accept_rate < 0.5 {
            failures.push(format!(
                "k{k}w{wbits}: accept rate {accept_rate:.2} < 0.50 on the predictable workload"
            ));
        }
    }
    // tripwire: the subsystem must beat the target somewhere in the sweep
    if best_bw < target_tok_s_bw {
        failures.push(format!(
            "best roofline {best_bw:.1} tok/s < target-alone {target_tok_s_bw:.1} tok/s"
        ));
    }
    if !failures.is_empty() {
        for f in &failures {
            eprintln!("spec_decode tripwire: {f}");
        }
        anyhow::bail!("{} spec_decode tripwire(s) fired", failures.len());
    }
    Ok(())
}
