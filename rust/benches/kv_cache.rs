//! KV-cache precision bench: for each `--kv-bits` setting, measure the
//! cache bytes/token and peak footprint, end-to-end native decode
//! throughput, and the attention error introduced by the quantized cache
//! (one decode step's logits vs the FP32 cache, same backend, same
//! inputs). Rows land in BENCH_kv.json via `util::bench::KvBenchRow`, so
//! the memory/accuracy/throughput trade-off is tracked across PRs. CI
//! smoke-runs this under FAST_BENCH=1 (sweeping 32 and 4 bits; the full
//! run adds 3 and 2).

use kllm::coordinator::{
    probe_decode_logits, AdmitPolicy, BackendSpec, DecodeBackend, Engine, EngineConfig,
    NativeCfg, NativeWaqBackend, Request,
};
use kllm::gemm::WaqBackend;
use kllm::kvcache::{KvBits, KvPrecision};
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::util::bench::{fast_mode, KvBenchRow};
use kllm::util::rng::Rng;
use kllm::util::stats::rel_l2_err;

fn build_backend(manifest: &Manifest, params: &ParamSet) -> anyhow::Result<NativeWaqBackend> {
    NativeWaqBackend::new(
        manifest,
        params,
        NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() },
    )
}

fn precision_of(backend: &NativeWaqBackend, bits: KvBits) -> KvPrecision {
    match bits {
        KvBits::Fp32 => KvPrecision::Fp32,
        q => KvPrecision::Quant(backend.kv_quantizer(q.bits())),
    }
}

/// One decode step's logits with the prefilled cache stored at `bits`
/// (the shared `probe_decode_logits` harness — same metric the accuracy
/// tests bound).
fn decode_logits_at(
    backend: &mut NativeWaqBackend,
    cfg: ModelCfg,
    bits: KvBits,
) -> anyhow::Result<Vec<f32>> {
    let prec = precision_of(backend, bits);
    let prompt: Vec<i32> = (0..12).map(|i| (i * 17 + 3) % cfg.vocab as i32).collect();
    probe_decode_logits(backend, prec, &prompt, 7)
}

fn main() -> anyhow::Result<()> {
    let cfg = ModelCfg::test_preset();
    let manifest = Manifest::synthetic("test", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let sweep: &[KvBits] = if fast_mode() {
        &[KvBits::Fp32, KvBits::B4]
    } else {
        &KvBits::ALL
    };
    let n_requests = if fast_mode() { 6 } else { 24 };
    let max_new = 8;

    // attention-error reference: the FP32-cache logits of one decode step
    let mut err_backend = build_backend(&manifest, &params)?;
    let fp32_logits = decode_logits_at(&mut err_backend, cfg, KvBits::Fp32)?;

    for &bits in sweep {
        let attn_rel_err = if bits == KvBits::Fp32 {
            0.0
        } else {
            let logits = decode_logits_at(&mut err_backend, cfg, bits)?;
            rel_l2_err(&logits, &fp32_logits)
        };

        // end-to-end native decode throughput at this cache precision
        let backend = build_backend(&manifest, &params)?;
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            backend: BackendSpec::Native(WaqBackend::Packed),
            kv_bits: bits,
            ..Default::default()
        };
        let mut engine = Engine::new(Box::new(backend), &ecfg);
        let mut rng = Rng::new(3);
        for id in 0..n_requests {
            let prompt: Vec<i32> = (0..4).map(|_| rng.below(cfg.vocab) as i32).collect();
            engine.submit(Request::new(id, prompt, max_new));
        }
        let t0 = std::time::Instant::now();
        engine.run_to_completion()?;
        let wall = t0.elapsed().as_secs_f64();
        let tokens = engine.stats.generated_tokens;
        let row = KvBenchRow {
            backend: engine.stats.waq_backend.to_string(),
            kv_bits: engine.stats.kv_bits,
            bytes_per_token: engine.stats.kv_bytes_per_token,
            peak_cache_bytes: engine.stats.peak_kv_bytes,
            decode_tok_s: tokens as f64 / wall.max(1e-12),
            attn_rel_err,
        };
        println!(
            "bench kv_cache/kv{bits:<4} {:8.1} tok/s  {:7.1} B/token  peak {:8} B  \
             attn rel err {:.4}",
            row.decode_tok_s, row.bytes_per_token, row.peak_cache_bytes, row.attn_rel_err,
        );
        row.append();
    }
    Ok(())
}
