//! Scheduler bench: decode inter-token latency under a prefill flood,
//! burst vs chunked. Rows land in BENCH_sched.json via
//! `util::bench::SchedBenchRow`.
//!
//! The question this bench answers is the one the chunked scheduler
//! exists for: when long prompts keep arriving, what happens to the
//! tokens/sec *experienced by requests already decoding*? Under the
//! phased burst loop an admitted prompt's whole prefill runs inside one
//! engine step, so every co-resident decode's next token waits for it —
//! the inter-token p99 inflates with prompt length. Under `--sched
//! chunked` each step carries at most a budgeted chunk of prefill rows
//! (auto-sized so one chunk costs about one decode step), bounding the
//! stall.
//!
//! Three scenarios, identical model and datapath (native packed WAQ,
//! synthetic params):
//!   * `decode-only`  — persistent decoders, no flood: the baseline
//!     inter-token latency of the datapath itself;
//!   * `mixed-flood` under `burst`    — informational (the spike we're
//!     converting into bounded per-step work);
//!   * `mixed-flood` under `chunked`  — the tripwired row.
//!
//! Latencies come from the engine's own `decode_lat` histogram — the
//! per-token gaps recorded at sampling time (recorded, not inferred
//! from totals), exactly what `{"cmd":"stats"}` reports in production.
//!
//! Tripwire (non-zero exit so CI fails on regression): chunked
//! mixed-flood p99 must stay within 6x the decode-only p99 plus a
//! 500us absolute floor (host-timer noise at microsecond scales). Burst
//! is exempt — its spike is the documented behavior chunked removes.

use kllm::coordinator::{
    AdmitPolicy, Engine, EngineConfig, NativeCfg, NativeWaqBackend, Request, SchedPolicy,
};
use kllm::gemm::WaqBackend;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::util::bench::{fast_mode, SchedBenchRow};
use kllm::util::rng::Rng;

/// Bench preset: the serving test shape with room for three persistent
/// decoders plus one flood slot, and enough context that long prompts
/// leave decode headroom.
fn bench_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 256,
        d_model: 64,
        n_layers: 2,
        n_heads: 4,
        seq_len: 64,
        batch: 2,
        decode_batch: 4,
        head_dim: 16,
        d_ff: 256,
        n_linears: 8,
    }
}

struct Workload {
    name: &'static str,
    /// tokens each persistent decoder generates
    decoder_tokens: usize,
    /// long-prompt requests injected while the decoders stream
    floods: usize,
    /// prompt length of each flood request
    flood_prompt: usize,
}

/// Run one scenario and return the engine (stats carry the histogram).
fn run_scenario(sched: SchedPolicy, w: &Workload, flood: bool) -> anyhow::Result<Engine> {
    let cfg = bench_cfg();
    let manifest = Manifest::synthetic("sched-bench", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let backend = NativeWaqBackend::new(
        &manifest,
        &params,
        NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() },
    )?;
    let ecfg = EngineConfig {
        policy: AdmitPolicy::FillAll,
        sched,
        prefill_chunk: 0, // auto budget: chunk cost ~ one decode step
        ..Default::default()
    };
    let mut e = Engine::new(Box::new(backend), &ecfg);
    // three persistent decoders with short prompts: the latency victims
    for id in 0..3u64 {
        e.submit(Request::new(id, vec![1 + id as i32, 5, 9, 13], w.decoder_tokens));
    }
    // warm the decoders into steady state before any flood arrives
    for _ in 0..4 {
        e.step()?;
    }
    let mut injected = 0usize;
    let mut since = 0usize;
    while e.has_work() {
        if flood && injected < w.floods && since >= 3 {
            let base = 20 + injected as i32;
            let prompt: Vec<i32> =
                (0..w.flood_prompt).map(|t| base + (t as i32) % 17).collect();
            e.submit(Request::new(100 + injected as u64, prompt, 2));
            injected += 1;
            since = 0;
        }
        e.step()?;
        since += 1;
    }
    anyhow::ensure!(
        e.stats.prefill_failures + e.stats.step_failures == 0,
        "scenario had failures"
    );
    Ok(e)
}

fn main() -> anyhow::Result<()> {
    let w = if fast_mode() {
        Workload { name: "fast", decoder_tokens: 24, floods: 4, flood_prompt: 24 }
    } else {
        Workload { name: "full", decoder_tokens: 48, floods: 12, flood_prompt: 32 }
    };

    let report = |label: &str, sched: SchedPolicy, scenario: &str, e: &Engine| -> (f64, f64) {
        let s = &e.stats;
        let (p50, p99) = (s.decode_lat.percentile(0.50), s.decode_lat.percentile(0.99));
        let row = SchedBenchRow {
            name: format!("sched/{}/{label}", w.name),
            sched: sched.to_string(),
            scenario: scenario.to_string(),
            prefill_chunk: 0,
            requests: s.completed,
            generated_tokens: s.generated_tokens,
            lat_count: s.decode_lat.count(),
            p50_s: p50,
            p99_s: p99,
        };
        println!(
            "bench scheduler/{}/{label:<16} p50 {:9.1}us  p99 {:9.1}us  ({} gaps)",
            w.name,
            p50 * 1e6,
            p99 * 1e6,
            row.lat_count
        );
        row.append();
        (p50, p99)
    };

    let base = run_scenario(SchedPolicy::Chunked, &w, false)?;
    let (_, base_p99) = report("decode-only", SchedPolicy::Chunked, "decode-only", &base);

    let burst = run_scenario(SchedPolicy::Burst, &w, true)?;
    report("burst-mixed", SchedPolicy::Burst, "mixed-flood", &burst);

    let chunked = run_scenario(SchedPolicy::Chunked, &w, true)?;
    let (_, chunked_p99) = report("chunked-mixed", SchedPolicy::Chunked, "mixed-flood", &chunked);

    anyhow::ensure!(
        base.stats.decode_lat.count() > 0 && chunked.stats.decode_lat.count() > 0,
        "histograms recorded nothing"
    );
    anyhow::ensure!(
        chunked.stats.prefills as usize >= 3 + w.floods,
        "the flood never prefilled"
    );
    // the tripwire: chunked keeps mixed-flood decode p99 near baseline
    let limit = base_p99 * 6.0 + 500e-6;
    if chunked_p99 > limit {
        anyhow::bail!(
            "scheduler tripwire: chunked mixed-flood p99 {:.1}us exceeds {:.1}us \
             (decode-only p99 {:.1}us x6 + 500us)",
            chunked_p99 * 1e6,
            limit * 1e6,
            base_p99 * 1e6
        );
    }
    Ok(())
}
