//! Minimal offline stand-in for the `anyhow` crate (the offline registry
//! ships no third-party crates). Implements exactly the API surface this
//! workspace uses — `Result`, `Error`, `anyhow!`, `bail!`, `Context`,
//! `Error::msg` — with the same semantics (message-carrying dynamic error,
//! context frames prepended, blanket `From` for std errors). Swap in the
//! real crate by retargeting the path dependency; no call site changes.

use std::fmt;

/// `Result<T, anyhow::Error>` with the error type defaulted, exactly like
/// the real crate (so `Result<String, String>` still names std's Result).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A message-carrying error. Context frames are folded into the message
/// (`outer: inner`), which is what both `{}` and `{:#}` render.
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable (mirrors `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }

    fn wrap<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Like the real anyhow: any std error converts, which is what makes `?`
// work on io/fmt/etc. results inside functions returning anyhow::Result.
// (Error itself deliberately does NOT implement std::error::Error, so this
// blanket impl cannot overlap the identity `From<T> for T`.)
impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// Attach context to an error (prepended to the message on failure).
pub trait Context<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: fmt::Display> Context<T, E> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::msg(e).wrap(f()))
    }
}

impl<T> Context<T, Error> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("fmt {args}")` or `anyhow!(displayable_expr)`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
}

/// `bail!(...)` = `return Err(anyhow!(...))`.
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// `ensure!(cond, ...)` = `if !cond { bail!(...) }`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*)
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/kllm")?;
        Ok(())
    }

    #[test]
    fn from_std_error_and_context() {
        let e = io_fail().context("reading config").unwrap_err();
        let s = format!("{e}");
        assert!(s.starts_with("reading config: "), "{s}");
        assert_eq!(format!("{e:#}"), s);
    }

    #[test]
    fn macros() {
        let e = anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = anyhow!("x = {x}");
        assert_eq!(e.to_string(), "x = 3");
        let e = anyhow!("{} {}", 1, 2);
        assert_eq!(e.to_string(), "1 2");
        let owned: String = "owned".into();
        let e = anyhow!(owned);
        assert_eq!(e.to_string(), "owned");
        fn bails() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(bails().unwrap_err().to_string(), "nope 7");
    }
}
