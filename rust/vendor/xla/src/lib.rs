//! API stub for the `xla` crate (xla_extension 0.5.1 PJRT bindings) so the
//! `pjrt` feature still type-checks in environments without the native
//! library. Every entry point returns `Error::Unavailable` at runtime; the
//! real binding is a drop-in replacement (same method surface as used by
//! `kllm::runtime::client`). Nothing here is compiled unless the `pjrt`
//! feature of the workspace is enabled.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    /// The native xla_extension library is not linked in this build.
    Unavailable(&'static str),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => write!(
                f,
                "xla stub: {what} requires the real xla_extension binding \
                 (this build vendored the offline API stub)"
            ),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &'static str) -> Result<T> {
    Err(Error::Unavailable(what))
}

/// Element types the runtime marshals (subset of XLA's primitive types).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    F16,
    F32,
    F64,
}

/// Host types that can cross the literal boundary.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

#[derive(Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    element_type: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn element_type(&self) -> ElementType {
        self.element_type
    }
}

#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable("Literal::reshape")
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        unavailable("Literal::array_shape")
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        unavailable("Literal::decompose_tuple")
    }
}

#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }

    pub fn execute_b<B>(&self, _args: &[B]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}
