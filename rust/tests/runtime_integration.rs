//! Integration: rust PJRT runtime executing the AOT artifacts (preset
//! `test`). Requires the `pjrt` feature and `make artifacts` to have run;
//! tests skip (with a note) otherwise so the offline tier-1 suite stays
//! green without the native xla binding.

use kllm::runtime::{artifacts_dir, pjrt_available, HostTensor, ParamSet, Runtime};
use kllm::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    if !pjrt_available() {
        eprintln!("skipping: kllm built without the `pjrt` feature");
        return None;
    }
    let dir = artifacts_dir("test");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/test missing — run `make artifacts` first");
        return None;
    }
    Some(Runtime::new(&dir).expect("pjrt runtime"))
}

fn tokens(rng: &mut Rng, b: usize, s: usize, vocab: usize) -> HostTensor {
    HostTensor::i32(
        (0..b * s).map(|_| rng.below(vocab) as i32).collect(),
        &[b, s],
    )
}

#[test]
fn fwd_produces_finite_logits() {
    let Some(mut rt) = runtime() else { return };
    let cfg = rt.manifest.model;
    let mut rng = Rng::new(1);
    let params = ParamSet::init(&rt.manifest, &mut rng);
    let mut inputs = params.tensors.clone();
    inputs.push(tokens(&mut rng, cfg.batch, cfg.seq_len, cfg.vocab));
    let out = rt.run("fwd", &inputs).expect("fwd run");
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape(), &[cfg.batch, cfg.seq_len, cfg.vocab]);
    assert!(out[0].as_f32().unwrap().iter().all(|v| v.is_finite()));
}

#[test]
fn loss_eval_matches_uniform_at_init() {
    let Some(mut rt) = runtime() else { return };
    let cfg = rt.manifest.model;
    let mut rng = Rng::new(2);
    let params = ParamSet::init(&rt.manifest, &mut rng);
    let toks = tokens(&mut rng, cfg.batch, cfg.seq_len, cfg.vocab);
    let mut inputs = params.tensors.clone();
    inputs.push(toks.clone());
    inputs.push(toks);
    let out = rt.run("loss_eval", &inputs).expect("loss_eval");
    let loss = out[0].as_f32().unwrap()[0];
    let uniform = (cfg.vocab as f32).ln();
    assert!(
        loss > 0.5 * uniform && loss < 2.0 * uniform,
        "loss {loss} vs ln(V) {uniform}"
    );
}

#[test]
fn train_step_decreases_loss() {
    let Some(mut rt) = runtime() else { return };
    let cfg = rt.manifest.model;
    let mut rng = Rng::new(3);
    let mut params = ParamSet::init(&rt.manifest, &mut rng);
    let mut m = ParamSet::zeros_like(&rt.manifest);
    let mut v = ParamSet::zeros_like(&rt.manifest);
    let toks = tokens(&mut rng, cfg.batch, cfg.seq_len, cfg.vocab);
    // next-token targets: shifted copy, last position masked
    let t = toks.as_i32().unwrap();
    let mut tg = vec![0i32; t.len()];
    for b in 0..cfg.batch {
        for s in 0..cfg.seq_len - 1 {
            tg[b * cfg.seq_len + s] = t[b * cfg.seq_len + s + 1];
        }
        tg[b * cfg.seq_len + cfg.seq_len - 1] = -1;
    }
    let targets = HostTensor::i32(tg, &[cfg.batch, cfg.seq_len]);

    let n = params.tensors.len();
    let mut losses = Vec::new();
    for step in 0..10 {
        let mut inputs = params.tensors.clone();
        inputs.extend(m.tensors.clone());
        inputs.extend(v.tensors.clone());
        inputs.push(HostTensor::scalar_f32((step + 1) as f32));
        inputs.push(HostTensor::scalar_f32(5e-3));
        inputs.push(toks.clone());
        inputs.push(targets.clone());
        let out = rt.run("train_step", &inputs).expect("train_step");
        assert_eq!(out.len(), 3 * n + 1);
        let mut it = out.into_iter();
        params.tensors = (&mut it).take(n).collect();
        m.tensors = (&mut it).take(n).collect();
        v.tensors = (&mut it).take(n).collect();
        losses.push(it.next().unwrap().as_f32().unwrap()[0]);
    }
    assert!(
        losses[9] < losses[0] - 0.2,
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn quantize_act_kernel_matches_rust_clustering_unit() {
    // Cross-layer check: the L1 Pallas Clustering-Unit kernel and the Rust
    // Codebook (the hardware's binary-search tree) agree index-for-index.
    let Some(mut rt) = runtime() else { return };
    let mut rng = Rng::new(4);
    let cb = kllm::quant::Codebook::new(rng.normal_vec(16, 1.0));
    let x: Vec<f32> = rng.normal_vec(128 * 256, 1.5);
    let out = rt
        .run(
            "quantize_act",
            &[
                HostTensor::f32(x.clone(), &[128, 256]),
                HostTensor::f32(cb.boundaries.clone(), &[15]),
            ],
        )
        .expect("quantize_act");
    let idx = out[0].as_i32().unwrap();
    for (i, (&xi, &got)) in x.iter().zip(idx).enumerate() {
        assert_eq!(got as u8, cb.assign(xi), "elem {i} x={xi}");
    }
}

#[test]
fn waq_gemm_kernel_matches_rust_datapath() {
    // The L1 fused kernel vs the Rust bit-exact LUT datapath.
    let Some(mut rt) = runtime() else { return };
    let spec = rt.manifest.artifact("waq_gemm").unwrap().clone();
    let (mm, kk, nn) = (
        spec.meta.get("M").unwrap().as_usize().unwrap(),
        spec.meta.get("K").unwrap().as_usize().unwrap(),
        spec.meta.get("N").unwrap().as_usize().unwrap(),
    );
    let mut rng = Rng::new(5);
    let cb_a = kllm::quant::Codebook::new(rng.normal_vec(16, 1.0));
    let cb_w = kllm::quant::Codebook::new(rng.normal_vec(16, 1.0));
    let a_idx: Vec<i32> = (0..mm * kk).map(|_| rng.below(16) as i32).collect();
    let w_idx: Vec<i32> = (0..kk * nn).map(|_| rng.below(16) as i32).collect();
    let a_scale: Vec<f32> = (0..mm).map(|_| 0.5 + rng.f32()).collect();
    let w_scale: Vec<f32> = (0..nn).map(|_| 0.5 + rng.f32()).collect();

    let out = rt
        .run(
            "waq_gemm",
            &[
                HostTensor::i32(a_idx.clone(), &[mm, kk]),
                HostTensor::i32(w_idx.clone(), &[kk, nn]),
                HostTensor::f32(cb_a.centroids.clone(), &[16]),
                HostTensor::f32(cb_w.centroids.clone(), &[16]),
                HostTensor::f32(a_scale.clone(), &[mm]),
                HostTensor::f32(w_scale.clone(), &[nn]),
            ],
        )
        .expect("waq_gemm");
    let got = out[0].as_f32().unwrap();

    // rust datapath, token by token
    let lut = kllm::gemm::CartesianLut::build(&cb_a, &cb_w);
    let qw = kllm::quant::QuantWeights {
        n_rows: kk,
        n_cols: nn,
        idx: w_idx.iter().map(|&v| v as u8).collect(),
        codebook: cb_w.clone(),
        col_scales: w_scale.clone(),
        group_size: 0,
        group_scales: vec![],
    };
    for mrow in 0..mm {
        let tok = kllm::quant::QuantToken {
            idx: a_idx[mrow * kk..(mrow + 1) * kk]
                .iter()
                .map(|&v| v as u8)
                .collect(),
            scale: a_scale[mrow],
            outliers: vec![],
        };
        let want = kllm::gemm::execute_direct(&tok, &qw, &lut);
        kllm::util::check::assert_allclose(
            &got[mrow * nn..(mrow + 1) * nn],
            &want,
            1e-4,
            1e-4,
            &format!("row {mrow}"),
        );
    }
}

#[test]
fn decode_step_is_consistent_with_prefill() {
    let Some(mut rt) = runtime() else { return };
    let cfg = rt.manifest.model;
    let mut rng = Rng::new(6);
    let params = ParamSet::init(&rt.manifest, &mut rng);

    // prefill a short prompt
    let plen = 5usize;
    let mut prompt = vec![0i32; cfg.seq_len];
    for p in prompt.iter_mut().take(plen) {
        *p = rng.below(cfg.vocab) as i32;
    }
    let mut inputs = params.tensors.clone();
    inputs.push(HostTensor::i32(prompt.clone(), &[1, cfg.seq_len]));
    inputs.push(HostTensor::scalar_i32(plen as i32));
    let out = rt.run("prefill", &inputs).expect("prefill");
    let (logits_last, kc, vc) = (&out[0], &out[1], &out[2]);
    assert_eq!(logits_last.shape(), &[cfg.vocab]);

    // decode the next token on slot 0
    let kvshape = [cfg.n_layers, cfg.decode_batch, cfg.n_heads, cfg.seq_len, cfg.head_dim];
    let per = cfg.n_heads * cfg.seq_len * cfg.head_dim;
    let mut kcb = HostTensor::zeros(&kvshape);
    let mut vcb = HostTensor::zeros(&kvshape);
    if let (HostTensor::F32 { data: kd, .. }, HostTensor::F32 { data: dst, .. }) =
        (kc, &mut kcb)
    {
        for l in 0..cfg.n_layers {
            let src = &kd[l * per..(l + 1) * per];
            dst[l * cfg.decode_batch * per..l * cfg.decode_batch * per + per]
                .copy_from_slice(src);
        }
    }
    if let (HostTensor::F32 { data: vd, .. }, HostTensor::F32 { data: dst, .. }) =
        (vc, &mut vcb)
    {
        for l in 0..cfg.n_layers {
            let src = &vd[l * per..(l + 1) * per];
            dst[l * cfg.decode_batch * per..l * cfg.decode_batch * per + per]
                .copy_from_slice(src);
        }
    }
    let next = argmax(logits_last.as_f32().unwrap()) as i32;
    let mut dinputs = params.tensors.clone();
    dinputs.push(kcb);
    dinputs.push(vcb);
    dinputs.push(HostTensor::i32(vec![next; cfg.decode_batch], &[cfg.decode_batch]));
    dinputs.push(HostTensor::i32(vec![plen as i32; cfg.decode_batch], &[cfg.decode_batch]));
    let dout = rt.run("decode_step", &dinputs).expect("decode_step");
    assert_eq!(dout[0].shape(), &[cfg.decode_batch, cfg.vocab]);

    // cross-check against full fwd over prompt + next token
    let mut full = prompt.clone();
    full[plen] = next;
    let mut finputs = params.tensors.clone();
    let mut batch_tokens = Vec::new();
    for _ in 0..cfg.batch {
        batch_tokens.extend_from_slice(&full);
    }
    finputs.push(HostTensor::i32(batch_tokens, &[cfg.batch, cfg.seq_len]));
    let fout = rt.run("fwd", &finputs).expect("fwd");
    let flog = fout[0].as_f32().unwrap();
    let want = &flog[plen * cfg.vocab..(plen + 1) * cfg.vocab];
    let got = &dout[0].as_f32().unwrap()[..cfg.vocab];
    kllm::util::check::assert_allclose(got, want, 2e-3, 2e-3, "decode vs fwd");
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}
