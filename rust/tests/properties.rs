//! Property-based invariants (via util::check's forall harness) over the
//! quantization library, the GEMM datapath, Orizuru, the simulator, and
//! the coordinator's slot/batching state machines.

use kllm::coordinator::{AdmitPolicy, Batcher, KvManager, Request};
use kllm::gemm::{self, CartesianLut};
use kllm::orizuru::Orizuru;
use kllm::quant::{self, Codebook, OutlierCfg, QuantToken, QuantWeights};
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::HostTensor;
use kllm::sim::{gemm_cost, HwConfig};
use kllm::tensor::Matrix;
use kllm::util::check::{assert_allclose, Check};
use kllm::util::rng::Rng;

// ---------------------------------------------------------------------------
// quantization invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_codebook_assignment_is_nearest() {
    Check::new(48).forall("nearest-centroid", |rng, _| {
        let bits = 2 + rng.below(3) as u32;
        let cb = Codebook::new(rng.normal_vec(1 << bits, 1.0));
        let x = rng.normal_f32() * 3.0;
        let got = cb.value(cb.assign(x));
        let best = cb
            .centroids
            .iter()
            .map(|&c| (x - c).abs())
            .fold(f32::INFINITY, f32::min);
        assert!(((x - got).abs() - best).abs() < 1e-6);
    });
}

#[test]
fn prop_weight_quant_error_bounded_by_scale() {
    Check::new(24).forall("wq-bounded", |rng, _| {
        let k = 8 + rng.below(48);
        let n = 4 + rng.below(24);
        let w = Matrix::random_normal(k, n, 0.5 + rng.f32(), rng);
        let q = quant::quantize_weights(&w, 4);
        let deq = q.dequantize();
        // per-element error can never exceed the channel scale (codebook
        // spans [-1, 1] after normalization; cell radius < 1)
        for c in 0..n {
            let s = q.col_scales[c];
            for r in 0..k {
                assert!(
                    (deq.at(r, c) - w.at(r, c)).abs() <= s + 1e-5,
                    "err beyond scale at ({r},{c})"
                );
            }
        }
    });
}

#[test]
fn prop_token_roundtrip_outliers_exact() {
    Check::new(32).forall("token-outliers-exact", |rng, _| {
        let d = 32 + rng.below(200);
        let x = rng.heavy_tailed_vec(d, 0.05, 10.0);
        let cb = Codebook::new(rng.normal_vec(16, 0.4));
        let cfg = OutlierCfg { total_frac: 0.02 + rng.f64() * 0.06 };
        let q = quant::quantize_token(&x, &cb, cfg);
        let deq = q.dequantize(&cb);
        for &(c, v, _) in &q.outliers {
            assert_eq!(deq[c as usize], v, "outlier channel {c} not FP-preserved");
        }
    });
}

// ---------------------------------------------------------------------------
// GEMM datapath invariants
// ---------------------------------------------------------------------------

fn random_gemm_case(rng: &mut Rng) -> (QuantToken, QuantWeights, CartesianLut, Vec<f32>, Matrix) {
    let k = 16 + rng.below(120);
    let n = 4 + rng.below(28);
    let w = Matrix::random_normal(k, n, 1.0, rng);
    let qw = quant::quantize_weights(&w, 4);
    let calib: Vec<Vec<f32>> = (0..4).map(|_| rng.heavy_tailed_vec(k, 0.02, 8.0)).collect();
    let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
    let cfg = OutlierCfg { total_frac: 0.04 };
    let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
    let x = rng.heavy_tailed_vec(k, 0.02, 8.0);
    let tok = quant::quantize_token(&x, &cb, cfg);
    let lut = CartesianLut::build(&cb, &qw.codebook);
    (tok, qw, lut, x, w)
}

#[test]
fn prop_direct_equals_histogram() {
    Check::new(24).forall("direct-vs-histogram", |rng, _| {
        let (tok, qw, lut, _, _) = random_gemm_case(rng);
        let d = gemm::execute_direct(&tok, &qw, &lut);
        let h = gemm::execute_histogram(&tok, &qw, &lut);
        assert_allclose(&d, &h, 1e-4, 1e-4, "direct vs histogram");
    });
}

#[test]
fn prop_dual_branch_equals_critical_path() {
    Check::new(24).forall("lookahead-equivalence", |rng, _| {
        let (tok, qw, lut, _, _) = random_gemm_case(rng);
        let a = gemm::execute_dual_branch(&tok, &qw, &lut);
        let b = gemm::execute_critical_path(&tok, &qw, &lut);
        assert_allclose(&a, &b, 1e-4, 1e-4, "dual vs critical");
    });
}

#[test]
fn prop_compensation_never_hurts() {
    Check::new(16).forall("compensation-helps", |rng, _| {
        let (tok, qw, lut, x, w) = random_gemm_case(rng);
        if tok.outliers.iter().all(|&(_, _, r)| r.abs() < 1e-3) {
            return; // no meaningful outliers this draw
        }
        let exact = Matrix::from_vec(1, x.len(), x.clone()).matmul(&w);
        let la = gemm::execute_direct(&tok, &qw, &lut);
        let dual = gemm::execute_dual_branch(&tok, &qw, &lut);
        let err = |v: &[f32]| -> f64 {
            v.iter()
                .zip(exact.row(0))
                .map(|(&a, &b)| ((a - b) as f64).powi(2))
                .sum()
        };
        assert!(err(&dual) <= err(&la) * 1.25 + 1e-6);
    });
}

// ---------------------------------------------------------------------------
// packed/tiled/threaded backend invariants (bit-exact vs execute_direct)
// ---------------------------------------------------------------------------

fn random_packed_case(
    rng: &mut Rng,
    a_bits: u32,
    w_bits: u32,
    batch: usize,
) -> (Vec<QuantToken>, QuantWeights, CartesianLut) {
    // odd and even K both drawn (odd exercises the packed tail byte)
    let k = 1 + rng.below(130);
    let n = 1 + rng.below(40);
    let w = Matrix::random_normal(k, n, 1.0, rng);
    let qw = quant::quantize_weights(&w, w_bits);
    let calib: Vec<Vec<f32>> = (0..4).map(|_| rng.heavy_tailed_vec(k, 0.02, 8.0)).collect();
    let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
    let cfg = OutlierCfg { total_frac: 0.05 };
    let cb = quant::learn_act_codebook(&refs, None, a_bits, cfg);
    let toks = (0..batch)
        .map(|_| quant::quantize_token(&rng.heavy_tailed_vec(k, 0.02, 8.0), &cb, cfg))
        .collect();
    let lut = CartesianLut::build(&cb, &qw.codebook);
    (toks, qw, lut)
}

#[test]
fn prop_packed_bit_exact_vs_direct() {
    Check::new(32).forall("packed-bit-exact", |rng, _| {
        // mixed bitwidths: 3/4-bit activations x 3/4-bit weights
        let a_bits = 3 + rng.below(2) as u32;
        let w_bits = 3 + rng.below(2) as u32;
        let (toks, qw, lut) = random_packed_case(rng, a_bits, w_bits, 1);
        let pw = qw.pack();
        let want = gemm::execute_direct(&toks[0], &qw, &lut);
        let got = gemm::execute_packed(&toks[0], &pw, &lut);
        assert_eq!(got, want, "A{a_bits}/W{w_bits} K={} N={}", qw.n_rows, qw.n_cols);
    });
}

#[test]
fn prop_tiled_threaded_bit_exact_vs_direct() {
    Check::new(20).forall("tiled-threaded-bit-exact", |rng, _| {
        let a_bits = 3 + rng.below(2) as u32;
        let batch = 1 + rng.below(16); // batch sizes 1..=16
        let (toks, qw, lut) = random_packed_case(rng, a_bits, 4, batch);
        let pw = qw.pack();
        let want: Vec<Vec<f32>> =
            toks.iter().map(|t| gemm::execute_direct(t, &qw, &lut)).collect();
        let cfg = gemm::TileCfg {
            n_block: 1 + rng.below(64),
            k_pair_block: 1 + rng.below(40),
            threads: 1 + rng.below(6),
        };
        let got = gemm::execute_batch_tiled(&toks, &pw, &lut, &cfg);
        assert_eq!(got, want, "batch={batch} cfg={cfg:?}");
    });
}

#[test]
fn prop_packed_outlier_tokens_compensate_identically() {
    // outlier-bearing tokens: the packed main branch composes with error
    // compensation exactly like the direct main branch
    Check::new(16).forall("packed-outlier-compensation", |rng, _| {
        let (toks, qw, lut) = random_packed_case(rng, 4, 4, 2);
        let pw = qw.pack();
        for tok in &toks {
            let want = gemm::execute_dual_branch(tok, &qw, &lut);
            let mut got = gemm::execute_packed(tok, &pw, &lut);
            gemm::compensate(&mut got, tok, &qw);
            assert_eq!(got, want);
        }
    });
}

#[test]
fn prop_packed_stream_roundtrip_any_width() {
    // the ONE packed representation (weights, KV payloads, shard slices):
    // pack/unpack identity at every width and length, and storage
    // accounting that matches the actual byte allocation
    Check::new(32).forall("packed-stream-roundtrip", |rng, _| {
        let bits = 2 + rng.below(3) as u32;
        let len = rng.below(300);
        let idx: Vec<u8> = (0..len).map(|_| rng.below(1 << bits) as u8).collect();
        let p = quant::PackedStream::pack(&idx, bits);
        assert_eq!(p.bits(), bits);
        assert_eq!(p.unpack(), idx);
        assert_eq!(p.storage_bytes(), p.bytes.len(), "accounting vs allocation");
        let per = if bits <= 2 { 4 } else { 2 };
        assert_eq!(p.storage_bytes(), len.div_ceil(per), "W{bits} len={len}");
        for (i, &v) in idx.iter().enumerate() {
            assert_eq!(p.get(i), v, "elem {i} at W{bits}");
        }
    });
}

#[test]
fn prop_packed_stream_slice_matches_repack() {
    // slice_cols is THE column-chunking primitive (shard splits and tile
    // ranges both ride on it): slicing a stream must equal packing the
    // sliced indices, at every width and for empty/full/interior ranges
    Check::new(32).forall("packed-stream-slice", |rng, _| {
        let bits = 2 + rng.below(3) as u32;
        let len = 1 + rng.below(200);
        let idx: Vec<u8> = (0..len).map(|_| rng.below(1 << bits) as u8).collect();
        let p = quant::PackedStream::pack(&idx, bits);
        let j0 = rng.below(len + 1);
        let j1 = j0 + rng.below(len + 1 - j0);
        let s = p.slice_cols(j0, j1);
        assert_eq!(s.bits(), bits);
        assert_eq!(s.unpack(), &idx[j0..j1], "{j0}..{j1} of {len} at W{bits}");
    });
}

// ---------------------------------------------------------------------------
// Orizuru invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_orizuru_matches_sort_oracle() {
    Check::new(32).forall("orizuru-oracle", |rng, _| {
        let n = 4 + rng.below(500);
        let k = 1 + rng.below(8).min(n / 2);
        let x = rng.normal_vec(n, 1.0);
        let mut o = Orizuru::new(&x);
        let (maxs, mins) = o.top_k(k);
        let mut sorted = x.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        for (i, &(_, v)) in maxs.iter().enumerate() {
            assert_eq!(v, sorted[n - 1 - i]);
        }
        for (i, &(_, v)) in mins.iter().enumerate() {
            assert_eq!(v, sorted[i]);
        }
    });
}

#[test]
fn prop_orizuru_comparison_model_holds() {
    Check::new(16).forall("orizuru-cost", |rng, _| {
        let n = 64 + rng.below(4000);
        let k = 1 + rng.below(16);
        let x = rng.normal_vec(n, 1.0);
        let mut o = Orizuru::new(&x);
        o.top_k(k);
        let model = Orizuru::paper_cost_model(n, k);
        let actual = o.comparisons() as f64;
        assert!(actual <= model * 1.05 + 8.0, "n={n} k={k}: {actual} vs {model}");
    });
}

// ---------------------------------------------------------------------------
// simulator invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_sim_monotone_in_work() {
    let hw = HwConfig::default();
    Check::new(24).forall("sim-monotone", |rng, _| {
        let k = 256 * (1 + rng.below(16));
        let n = 256 * (1 + rng.below(16));
        let a = gemm_cost(&hw, 1, k, n, 4, 0.01);
        let b = gemm_cost(&hw, 1, k * 2, n, 4, 0.01);
        let c = gemm_cost(&hw, 1, k, n * 2, 4, 0.01);
        assert!(b.total_lookahead() >= a.total_lookahead());
        assert!(c.total_lookahead() >= a.total_lookahead());
        // critical path is never faster than look-ahead
        assert!(a.total_critical_path() >= a.total_lookahead());
    });
}

#[test]
fn prop_sim_outlier_fraction_monotone() {
    let hw = HwConfig::default();
    Check::new(16).forall("sim-outlier-monotone", |rng, _| {
        let k = 1024 * (1 + rng.below(4));
        let f1 = 0.005 + rng.f64() * 0.02;
        let f2 = f1 * (2.0 + rng.f64());
        let a = gemm_cost(&hw, 1, k, 4096, 4, f1);
        let b = gemm_cost(&hw, 1, k, 4096, 4, f2);
        assert!(b.outlier.total() >= a.outlier.total());
    });
}

// ---------------------------------------------------------------------------
// coordinator state-machine invariants (no PJRT needed)
// ---------------------------------------------------------------------------

fn test_cfg() -> ModelCfg {
    ModelCfg {
        vocab: 64, d_model: 16, n_layers: 2, n_heads: 2, seq_len: 16,
        batch: 2, decode_batch: 3, head_dim: 8, d_ff: 64, n_linears: 8,
    }
}

#[test]
fn prop_kv_slots_never_leak() {
    Check::new(24).forall("kv-no-leak", |rng, _| {
        let cfg = test_cfg();
        let mut kv = KvManager::new(cfg);
        let shape = [cfg.n_layers, 1, cfg.n_heads, cfg.seq_len, cfg.head_dim];
        let nelem: usize = shape.iter().product();
        let mut active = 0usize;
        for step in 0..200 {
            if rng.f64() < 0.5 {
                if let Some(slot) = kv.free_slot() {
                    let kc = HostTensor::f32(vec![1.0; nelem], &shape);
                    let vc = HostTensor::f32(vec![2.0; nelem], &shape);
                    let plen = 1 + rng.below(cfg.seq_len - 2);
                    kv.install_prefill(slot, step as u64, plen, &kc, &vc).unwrap();
                    active += 1;
                }
            } else {
                // release a random active slot
                let occupied: Vec<usize> = (0..cfg.decode_batch)
                    .filter(|&s| kv.position(s).is_some())
                    .collect();
                if !occupied.is_empty() {
                    kv.release(*rng.choice(&occupied));
                    active -= 1;
                }
            }
            assert_eq!(kv.active_count(), active);
            assert!(active <= cfg.decode_batch);
        }
    });
}

#[test]
fn prop_batcher_fifo_and_bounded() {
    Check::new(24).forall("batcher-fifo", |rng, _| {
        let mut b = Batcher::new(if rng.f64() < 0.5 {
            AdmitPolicy::OnePerStep
        } else {
            AdmitPolicy::FillAll
        });
        let mut next_id = 0u64;
        let mut last_admitted = None::<u64>;
        for _ in 0..100 {
            if rng.f64() < 0.6 {
                b.enqueue(Request::new(next_id, vec![1], 4));
                next_id += 1;
            } else {
                let free = rng.below(5);
                let admitted = b.admit(free);
                assert!(admitted.len() <= free);
                for r in admitted {
                    if let Some(prev) = last_admitted {
                        assert!(r.id > prev, "FIFO violated: {} after {}", r.id, prev);
                    }
                    last_admitted = Some(r.id);
                }
            }
        }
    });
}

#[test]
fn prop_woq_lut_gemv_matches_dot() {
    Check::new(24).forall("woq-correct", |rng, _| {
        let k = 4 + rng.below(100);
        let n = 1 + rng.below(12);
        let bits = 3 + rng.below(2) as u32;
        let mu = [2usize, 4, 8][rng.below(3)];
        let x = rng.normal_vec(k, 1.0);
        let w_q: Vec<i8> = (0..k * n)
            .map(|_| (rng.below(1 << bits) as i32 - (1 << (bits - 1))) as i8)
            .collect();
        let got = gemm::woq::woq_lut_gemv(&x, &w_q, n, bits, mu);
        let mut want = vec![0.0f32; n];
        for j in 0..n {
            want[j] = (0..k).map(|i| x[i] * w_q[i * n + j] as f32).sum();
        }
        assert_allclose(&got, &want, 1e-4, 1e-3, "woq vs dot");
    });
}

// ---------------------------------------------------------------------------
// paged KV-cache allocator invariants (no PJRT needed)
// ---------------------------------------------------------------------------

/// Random admit / decode-append / abort sequences over the paged cache:
/// no block leaks (in-use count == blocks listed in tables), no double
/// assignment (every live block id appears in exactly one table), and
/// block-table bounds (written <= seq_len, table length == exactly the
/// blocks needed to cover the written positions).
#[test]
fn prop_paged_kv_no_leaks_no_double_assignment_bounded_tables() {
    use kllm::kvcache::{KvPrecision, KvQuantizer};
    Check::new(16).forall("paged-kv", |rng, case| {
        // seq_len > block_tokens (16): tables must cross block
        // boundaries, or multi-block release/append bugs go unchallenged
        let cfg = ModelCfg { seq_len: 40, ..test_cfg() };
        let precision = match case % 3 {
            0 => KvPrecision::Fp32,
            1 => KvPrecision::Quant(KvQuantizer::uniform(
                cfg.n_layers,
                cfg.n_heads,
                cfg.head_dim,
                4,
            )),
            _ => KvPrecision::Quant(
                KvQuantizer::uniform(cfg.n_layers, cfg.n_heads, cfg.head_dim, 2)
                    .with_outliers(1),
            ),
        };
        let mut kv = KvManager::with_precision(cfg, precision);
        let d = cfg.n_heads * cfg.head_dim;
        let shape = [cfg.n_layers, 1, cfg.n_heads, cfg.seq_len, cfg.head_dim];
        let nelem: usize = shape.iter().product();
        let bt = kv.cache().block_tokens();
        for step in 0..120 {
            let r = rng.f64();
            if r < 0.35 {
                // admit: prefill a free slot at a random prompt length
                if let Some(slot) = kv.free_slot() {
                    let kc = HostTensor::f32(rng.normal_vec(nelem, 1.0), &shape);
                    let vc = HostTensor::f32(rng.normal_vec(nelem, 1.0), &shape);
                    let plen = 1 + rng.below(cfg.seq_len - 2);
                    kv.install_prefill(slot, step as u64, plen, &kc, &vc).unwrap();
                }
            } else if r < 0.75 {
                // decode: append one position to every active slot (all
                // layers), mirroring the engine's step protocol
                for slot in 0..cfg.decode_batch {
                    let Some(pos) = kv.position(slot) else { continue };
                    if pos >= cfg.seq_len - 1 {
                        kv.release(slot); // exhausted, as the engine would
                        continue;
                    }
                    let krow = rng.normal_vec(d, 1.0);
                    let vrow = rng.normal_vec(d, 1.0);
                    for l in 0..cfg.n_layers {
                        kv.append_token(l, slot, pos, &krow, &vrow).unwrap();
                    }
                    kv.advance(slot).unwrap();
                }
            } else {
                // abort a random active slot
                let occupied: Vec<usize> = (0..cfg.decode_batch)
                    .filter(|&s| kv.position(s).is_some())
                    .collect();
                if !occupied.is_empty() {
                    kv.release(*rng.choice(&occupied));
                }
            }

            // ---- invariants ------------------------------------------
            let c = kv.cache();
            let mut seen = std::collections::HashSet::new();
            let mut listed = 0usize;
            for slot in 0..cfg.decode_batch {
                for l in 0..cfg.n_layers {
                    let written = c.written(l, slot);
                    let blocks = c.slot_blocks(l, slot);
                    assert!(written <= cfg.seq_len, "written out of bounds");
                    assert_eq!(
                        blocks.len(),
                        written.div_ceil(bt),
                        "table covers exactly the written positions"
                    );
                    if kv.position(slot).is_none() {
                        assert_eq!(written, 0, "freed slot still has rows");
                    }
                    for &b in blocks {
                        assert!(
                            (b as usize) < c.capacity_blocks(),
                            "block id beyond pool"
                        );
                        assert!(seen.insert(b), "block {b} assigned twice");
                    }
                    listed += blocks.len();
                }
            }
            assert_eq!(listed, c.in_use_blocks(), "block leak: listed != in-use");
        }
    });
}

/// Any-bit packed GEMM property net (the tentpole acceptance sweep):
/// random shapes (odd and even K — odd exercises the packed tail rows —
/// batch 1..=16, 2/3/4-bit activations) crossed with every weight width
/// in {2,3,4} × per-group scale grids {whole-row, 32, 128} × outliers
/// on/off. The unified packed kernel + outlier compensation must be
/// bit-identical to the direct dual-branch reference, and so must every
/// column-sharded split built via `from_packed` (including
/// `cols < shards` and `cols % shards != 0`).
#[test]
fn prop_any_bit_gemm_bit_exact_sharded_and_unsharded() {
    use kllm::gemm::{ShardPool, ShardedWaqGemm, TileCfg};
    use std::sync::Arc;

    // 18 cases tile the full {w_bits} x {group} x {outliers} grid once
    Check::new(18).forall("any-bit-gemm-bit-exact", |rng, case| {
        let k = 1 + rng.below(130);
        let n = 1 + rng.below(40);
        let batch = 1 + rng.below(16);
        let a_bits = 2 + rng.below(3) as u32;
        let w_bits = 2 + (case % 3) as u32;
        let group = [0usize, 32, 128][(case / 3) % 3];
        let outliers_on = case / 9 == 0;
        let w = Matrix::random_normal(k, n, 1.0, rng);
        let qw = quant::quantize_weights_grouped(&w, None, w_bits, group);
        let calib: Vec<Vec<f32>> =
            (0..4).map(|_| rng.heavy_tailed_vec(k, 0.02, 8.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let ocfg = OutlierCfg { total_frac: 0.05 };
        let cb = quant::learn_act_codebook(&refs, None, a_bits, ocfg);
        let toks: Vec<QuantToken> = (0..batch)
            .map(|_| {
                let x = rng.heavy_tailed_vec(k, 0.02, 8.0);
                if outliers_on {
                    quant::quantize_token(&x, &cb, ocfg)
                } else {
                    quant::quantize_token_with_outliers(&x, &cb, &[])
                }
            })
            .collect();
        let lut = CartesianLut::build(&cb, &qw.codebook);
        let pw = qw.pack();
        assert_eq!(pw.bits(), w_bits, "pack() follows the codebook width");
        let want: Vec<Vec<f32>> =
            toks.iter().map(|t| gemm::execute_dual_branch(t, &qw, &lut)).collect();

        // unsharded any-bit kernel at a random tiling
        let tcfg = TileCfg {
            n_block: 1 + rng.below(64),
            k_pair_block: 1 + rng.below(40),
            threads: 1 + rng.below(4),
        };
        let mut got = gemm::execute_batch_tiled(&toks, &pw, &lut, &tcfg);
        for (o, t) in got.iter_mut().zip(&toks) {
            gemm::compensate_packed(o, t, &pw);
        }
        assert_eq!(
            got, want,
            "K={k} N={n} A{a_bits}/W{w_bits} group={group} batch={batch} \
             outliers={outliers_on} cfg={tcfg:?}"
        );

        // every sharded split of the same packed weights
        for shards in [1usize, 3] {
            let pool = Arc::new(ShardPool::new(shards).expect("pool"));
            let sh = ShardedWaqGemm::from_packed(&pw, &lut, shards, pool).expect("shard");
            assert_eq!(
                sh.execute_batch(&toks),
                want,
                "K={k} N={n} A{a_bits}/W{w_bits} group={group} batch={batch} \
                 shards={shards} outliers={outliers_on}"
            );
        }
    });
}

/// Prefix-cache refcount audit: random admit / shared-prefix fork /
/// divergent-append (copy-on-write) / register / abort / evict
/// interleavings must never leak or double-free a block. Ground truth is
/// holder-counting — for every live block id, the allocator's refcount
/// must equal the number of slot-table entries referencing it plus the
/// number of prefix-index node references. (The no-double-assignment
/// invariant of the non-prefix test is deliberately *relaxed* here:
/// aliasing shared blocks across tables is the whole point.)
#[test]
fn prop_prefix_refcounts_balance_holders_no_leak_no_double_free() {
    use kllm::kvcache::{KvPrecision, KvQuantizer};
    use std::collections::HashMap;

    fn audit(kv: &KvManager, cfg: &ModelCfg) {
        let c = kv.cache();
        let mut holders: HashMap<u32, usize> = HashMap::new();
        for slot in 0..cfg.decode_batch {
            for l in 0..cfg.n_layers {
                for &b in c.slot_blocks(l, slot) {
                    *holders.entry(b).or_insert(0) += 1;
                }
            }
        }
        for b in c.prefix_block_refs() {
            *holders.entry(b).or_insert(0) += 1;
        }
        // leak = allocator thinks a block is live that no holder lists
        assert_eq!(holders.len(), c.in_use_blocks(), "live set vs allocator in-use");
        for (&b, &n) in &holders {
            assert_eq!(c.block_ref_count(b), n, "block {b}: refcount vs holders");
        }
    }

    Check::new(12).forall("prefix-refcount", |rng, case| {
        let cfg = ModelCfg { seq_len: 40, ..test_cfg() };
        let precision = if case % 2 == 0 {
            KvPrecision::Fp32
        } else {
            KvPrecision::Quant(KvQuantizer::uniform(
                cfg.n_layers,
                cfg.n_heads,
                cfg.head_dim,
                4,
            ))
        };
        let mut kv = KvManager::with_precision_opts(cfg, precision, true);
        let d = cfg.n_heads * cfg.head_dim;
        // a small pool of shared prompt heads: draws collide constantly,
        // so admissions fork off cached prefixes and COW fires both at
        // partial-block admission tails and at divergent decode appends
        let heads: Vec<Vec<i32>> = (0..3)
            .map(|h| (0..24).map(|i| (h * 100 + i) as i32).collect())
            .collect();
        let mut next_req = 0u64;
        for _ in 0..140 {
            let r = rng.f64();
            if r < 0.40 {
                // admit: pooled head prefix + random tail, then "prefill"
                // the uncached remainder through the COW append path
                if let Some(slot) = kv.free_slot() {
                    let head = &heads[rng.below(heads.len())];
                    let mut prompt = head[..1 + rng.below(head.len())].to_vec();
                    for _ in 0..rng.below(8) {
                        prompt.push(rng.below(64) as i32);
                    }
                    prompt.truncate(cfg.seq_len - 2);
                    let plen = prompt.len();
                    let m = kv.admit_prefix(slot, next_req, &prompt, plen).unwrap();
                    next_req += 1;
                    assert!(m.tokens < plen, "at least one token is computed");
                    let mut aborted = false;
                    'fill: for pos in m.tokens..plen {
                        for l in 0..cfg.n_layers {
                            let krow = rng.normal_vec(d, 1.0);
                            let vrow = rng.normal_vec(d, 1.0);
                            if kv.append_token(l, slot, pos, &krow, &vrow).is_err() {
                                // genuine pool pressure (COW can need one
                                // block beyond capacity): abort the admit,
                                // as the engine does on prefill failure
                                kv.release(slot);
                                aborted = true;
                                break 'fill;
                            }
                        }
                    }
                    if !aborted {
                        kv.set_position(slot, plen).unwrap();
                        // some requests finish unregistered (engine aborts
                        // before registration): both paths must balance
                        if rng.f64() < 0.7 {
                            kv.register_prefix(slot, &prompt);
                        }
                    }
                }
            } else if r < 0.75 {
                // decode: divergent append on every active slot
                for slot in 0..cfg.decode_batch {
                    let Some(pos) = kv.position(slot) else { continue };
                    if pos >= cfg.seq_len - 1 {
                        kv.release(slot);
                        continue;
                    }
                    let krow = rng.normal_vec(d, 1.0);
                    let vrow = rng.normal_vec(d, 1.0);
                    let mut ok = true;
                    for l in 0..cfg.n_layers {
                        if kv.append_token(l, slot, pos, &krow, &vrow).is_err() {
                            kv.release(slot);
                            ok = false;
                            break;
                        }
                    }
                    if ok {
                        kv.advance(slot).unwrap();
                    }
                }
            } else if r < 0.90 {
                // abort a random active slot without registering
                let occupied: Vec<usize> = (0..cfg.decode_batch)
                    .filter(|&s| kv.position(s).is_some())
                    .collect();
                if !occupied.is_empty() {
                    kv.release(*rng.choice(&occupied));
                }
            } else {
                // chaos-style LRU pressure on the index
                kv.cache_mut().evict_cached(1 + rng.below(4));
            }
            audit(&kv, &cfg);
        }
        // drain: release every slot, then evict the index dry — every
        // block must come home, every node must go
        for slot in 0..cfg.decode_batch {
            if kv.position(slot).is_some() {
                kv.release(slot);
            }
        }
        kv.cache_mut().evict_cached(usize::MAX);
        assert_eq!(kv.cache().in_use_blocks(), 0, "leaked blocks at drain");
        assert_eq!(kv.cache().prefix_nodes(), 0, "stranded index nodes at drain");
    });
}

/// Speculative rollback-safety audit (the tentpole's KV contract): random
/// propose / accept / reject / deep-truncate / abort interleavings over a
/// prefix-sharing paged cache. After every operation the allocator's
/// refcounts must balance the holders (no leak, no double free), a drain
/// must return every block, and — the immutability bar — blocks shared
/// with the registered canonical prefix must never be mutated: a slot
/// forked off that prefix always reads back the exact stored payload, no
/// matter how many speculative appends and truncates ran over aliased
/// tails in between. Rows are a pure function of (token history, layer),
/// like a real model's, so any corruption shows up as a content mismatch.
#[test]
fn prop_speculative_rollback_refcounts_balance_and_prefix_blocks_immutable() {
    use kllm::kvcache::{KvPrecision, KvQuantizer};
    use std::collections::HashMap;

    fn rows_for(hist: &[i32], layer: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
        let mut h = 0xcbf29ce484222325u64 ^ (layer as u64).wrapping_mul(0x9e3779b9);
        for &t in hist {
            h = (h ^ t as u64).wrapping_mul(0x100000001b3);
        }
        let mut rng = Rng::new(h);
        (rng.normal_vec(d, 1.0), rng.normal_vec(d, 1.0))
    }

    fn audit(kv: &KvManager, cfg: &ModelCfg) {
        let c = kv.cache();
        let mut holders: HashMap<u32, usize> = HashMap::new();
        for slot in 0..cfg.decode_batch {
            for l in 0..cfg.n_layers {
                for &b in c.slot_blocks(l, slot) {
                    *holders.entry(b).or_insert(0) += 1;
                }
            }
        }
        for b in c.prefix_block_refs() {
            *holders.entry(b).or_insert(0) += 1;
        }
        assert_eq!(holders.len(), c.in_use_blocks(), "live set vs allocator in-use");
        for (&b, &n) in &holders {
            assert_eq!(c.block_ref_count(b), n, "block {b}: refcount vs holders");
        }
    }

    Check::new(10).forall("spec-rollback", |rng, case| {
        let cfg = ModelCfg { seq_len: 40, ..test_cfg() };
        let precision = if case % 2 == 0 {
            KvPrecision::Fp32
        } else {
            KvPrecision::Quant(KvQuantizer::uniform(
                cfg.n_layers,
                cfg.n_heads,
                cfg.head_dim,
                4,
            ))
        };
        let mut kv = KvManager::with_precision_opts(cfg, precision, true);
        let d = cfg.n_heads * cfg.head_dim;
        let (nl, nb, nh, sl, hd) =
            (cfg.n_layers, cfg.decode_batch, cfg.n_heads, cfg.seq_len, cfg.head_dim);
        let flat = |l: usize, s: usize, h: usize, pos: usize| -> usize {
            ((((l * nb) + s) * nh + h) * sl + pos) * hd
        };

        // canonical shared prefix, computed once and registered
        let prefix: Vec<i32> = (0..24).map(|i| 7 + i as i32).collect();
        let plen = prefix.len();
        let s0 = kv.free_slot().expect("empty cache has a free slot");
        let m = kv.admit_prefix(s0, 0, &prefix, plen).unwrap();
        assert_eq!(m.tokens, 0, "cold admission computes everything");
        for pos in 0..plen {
            for l in 0..nl {
                let (krow, vrow) = rows_for(&prefix[..=pos], l, d);
                kv.append_token(l, s0, pos, &krow, &vrow).unwrap();
            }
        }
        kv.set_position(s0, plen).unwrap();
        kv.register_prefix(s0, &prefix);
        // the *stored* payload (post-quantization for n-bit streams) is
        // the ground truth every later forked read must reproduce
        let (ksnap, vsnap) = kv.dense_tensors();
        let (ksnap, vsnap) =
            (ksnap.as_f32().unwrap().to_vec(), vsnap.as_f32().unwrap().to_vec());
        kv.release(s0);
        audit(&kv, &cfg);

        // per-slot token history (committed + uncommitted speculation)
        let mut hist: Vec<Option<Vec<i32>>> = vec![None; nb];
        let mut next_req = 1u64;
        for _ in 0..140 {
            let r = rng.f64();
            if r < 0.35 {
                // fork: canonical head slice + random tail, then check the
                // aliased canonical positions against the snapshot
                let Some(slot) = kv.free_slot() else { continue };
                let head_len = 1 + rng.below(plen);
                let mut prompt = prefix[..head_len].to_vec();
                for _ in 0..rng.below(6) {
                    prompt.push(rng.below(64) as i32);
                }
                prompt.truncate(cfg.seq_len - 8);
                let pl = prompt.len();
                let m = kv.admit_prefix(slot, next_req, &prompt, pl).unwrap();
                next_req += 1;
                assert!(m.tokens < pl, "match capped at plen-1");
                let (kd, vd) = kv.dense_tensors();
                let (kd, vd) = (kd.as_f32().unwrap(), vd.as_f32().unwrap());
                for pos in 0..m.tokens.min(head_len) {
                    for l in 0..nl {
                        for h in 0..nh {
                            let a = flat(l, slot, h, pos);
                            let b = flat(l, s0, h, pos);
                            assert_eq!(
                                &kd[a..a + hd],
                                &ksnap[b..b + hd],
                                "shared prefix K mutated: l{l} h{h} pos{pos}"
                            );
                            assert_eq!(
                                &vd[a..a + hd],
                                &vsnap[b..b + hd],
                                "shared prefix V mutated: l{l} h{h} pos{pos}"
                            );
                        }
                    }
                }
                // compute the uncached tail (COW on partial blocks)
                let mut ok = true;
                'fill: for pos in m.tokens..pl {
                    for l in 0..nl {
                        let (krow, vrow) = rows_for(&prompt[..=pos], l, d);
                        if kv.append_token(l, slot, pos, &krow, &vrow).is_err() {
                            kv.release(slot); // genuine pool pressure
                            ok = false;
                            break 'fill;
                        }
                    }
                }
                if ok {
                    kv.set_position(slot, pl).unwrap();
                    kv.register_prefix(slot, &prompt);
                    hist[slot] = Some(prompt);
                } else {
                    hist[slot] = None;
                }
            } else if r < 0.70 {
                // speculative round: propose k, then accept a random
                // prefix of the proposals (rollback via truncate)
                let active: Vec<usize> =
                    (0..nb).filter(|&s| hist[s].is_some()).collect();
                if active.is_empty() {
                    continue;
                }
                let slot = *rng.choice(&active);
                let base = kv.position(slot).unwrap();
                let window = (cfg.seq_len - 1).saturating_sub(base).min(4);
                if window == 0 {
                    kv.release(slot);
                    hist[slot] = None;
                    continue;
                }
                let k = 1 + rng.below(window);
                let mut h = hist[slot].clone().unwrap();
                let mut ok = true;
                'prop: for i in 0..k {
                    h.push(rng.below(64) as i32);
                    for l in 0..nl {
                        let (krow, vrow) = rows_for(&h, l, d);
                        if kv.append_token(l, slot, base + i, &krow, &vrow).is_err() {
                            kv.release(slot);
                            hist[slot] = None;
                            ok = false;
                            break 'prop;
                        }
                    }
                }
                if ok {
                    kv.set_position(slot, base + k).unwrap();
                    let acc = rng.below(k + 1);
                    kv.truncate(slot, base + acc).unwrap();
                    h.truncate(base + acc);
                    hist[slot] = Some(h);
                }
            } else if r < 0.80 {
                // deep rollback, possibly below the aliased prefix region
                let active: Vec<usize> =
                    (0..nb).filter(|&s| hist[s].is_some()).collect();
                if active.is_empty() {
                    continue;
                }
                let slot = *rng.choice(&active);
                let pos = kv.position(slot).unwrap();
                let new_len = 1 + rng.below(pos.max(1));
                kv.truncate(slot, new_len).unwrap();
                hist[slot].as_mut().unwrap().truncate(new_len);
            } else if r < 0.92 {
                // abort mid-speculation
                let active: Vec<usize> =
                    (0..nb).filter(|&s| hist[s].is_some()).collect();
                if !active.is_empty() {
                    let slot = *rng.choice(&active);
                    kv.release(slot);
                    hist[slot] = None;
                }
            } else {
                // LRU pressure on the index
                kv.cache_mut().evict_cached(1);
            }

            for slot in 0..nb {
                match &hist[slot] {
                    Some(h) => assert_eq!(
                        kv.position(slot),
                        Some(h.len()),
                        "slot {slot}: position vs tracked history"
                    ),
                    None => assert!(kv.position(slot).is_none(), "slot {slot} not free"),
                }
            }
            audit(&kv, &cfg);
        }

        // drain: every block comes home, every index node goes
        for slot in 0..nb {
            if kv.position(slot).is_some() {
                kv.release(slot);
            }
        }
        kv.cache_mut().evict_cached(usize::MAX);
        assert_eq!(kv.cache().in_use_blocks(), 0, "leaked blocks at drain");
        assert_eq!(kv.cache().prefix_nodes(), 0, "stranded index nodes at drain");
    });
}
