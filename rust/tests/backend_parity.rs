//! Backend-parity and native-datapath integration tests. Everything here
//! runs in the default (featureless) build: the PJRT side uses
//! `PjrtBackend::stub` (the artifact-contract test double) and the native
//! side needs no artifacts at all (`Manifest::synthetic`).

use std::sync::atomic::Ordering;

use kllm::coordinator::{
    AdmitPolicy, BackendSpec, Coordinator, DecodeBackend, Engine, EngineConfig, FinishReason,
    KvManager, NativeCfg, NativeWaqBackend, PjrtBackend, PrefillOut, Request, Response,
    ShardedWaqBackend, StepCost,
};
use kllm::gemm::WaqBackend;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::sim::OasisMode;
use kllm::util::rng::Rng;

fn tiny_cfg(decode_batch: usize) -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        seq_len: 16,
        batch: 1,
        decode_batch,
        head_dim: 16,
        d_ff: 128,
        n_linears: 8,
    }
}

fn native_backend(cfg: ModelCfg, waq: WaqBackend) -> NativeWaqBackend {
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    NativeWaqBackend::new(&manifest, &params, NativeCfg { waq, ..NativeCfg::default() })
        .expect("native backend build")
}

fn stub_backend(cfg: ModelCfg) -> PjrtBackend {
    PjrtBackend::stub(cfg, WaqBackend::Packed, OasisMode::a4())
}

/// Same synthetic model + quantization config as [`native_backend`], but
/// with every linear split into `shards` tensor-parallel column shards.
fn sharded_backend(cfg: ModelCfg, shards: usize) -> ShardedWaqBackend {
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    ShardedWaqBackend::new(&manifest, &params, NativeCfg::default(), shards)
        .expect("sharded backend build")
}

/// Submit the same seeded request stream and drain the engine.
fn run_stream(engine: &mut Engine, vocab: usize) -> Vec<Response> {
    let mut rng = Rng::new(9);
    for id in 0..6u64 {
        let plen = 1 + rng.below(5);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        engine.submit(Request::new(id, prompt, 3 + rng.below(4)));
    }
    let mut out = engine.run_to_completion().expect("run");
    out.sort_by_key(|r| r.id);
    out
}

#[test]
fn stub_and_native_drive_identical_engine_bookkeeping() {
    let cfg = tiny_cfg(2);
    let ecfg = EngineConfig::default();
    let mut stub = Engine::new(Box::new(stub_backend(cfg)), &ecfg);
    let mut native = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
    let a = run_stream(&mut stub, cfg.vocab);
    let b = run_stream(&mut native, cfg.vocab);

    // token *values* differ (different logits); the engine bookkeeping —
    // admission order, slot lifecycle, finish reasons, token counts —
    // must be identical for the same request stream
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.id, rb.id);
        assert_eq!(ra.prompt_len, rb.prompt_len);
        assert_eq!(ra.tokens.len(), rb.tokens.len(), "request {}", ra.id);
        assert_eq!(ra.finish_reason, rb.finish_reason, "request {}", ra.id);
    }
    assert_eq!(stub.stats.prefills, native.stats.prefills);
    assert_eq!(stub.stats.decode_steps, native.stats.decode_steps);
    assert_eq!(stub.stats.generated_tokens, native.stats.generated_tokens);
    assert_eq!(stub.stats.occupancy_sum, native.stats.occupancy_sum);
    assert_eq!(stub.stats.completed, native.stats.completed);
    assert_eq!(stub.active_count(), 0);
    assert_eq!(native.active_count(), 0);
    // same modeled accelerator work, different host-clock semantics
    assert!((stub.sim.seconds - native.sim.seconds).abs() < 1e-12);
    assert_eq!(stub.stats.waq_backend, "packed");
    assert_eq!(native.stats.waq_backend, "native-packed");
}

#[test]
fn native_greedy_decode_deterministic_across_batch_sizes() {
    let cfg = tiny_cfg(4);
    let ecfg = EngineConfig { policy: AdmitPolicy::FillAll, ..Default::default() };
    let probe = vec![3i32, 14, 15];
    let solo = {
        let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
        e.submit(Request::new(0, probe.clone(), 6));
        e.run_to_completion().expect("solo")[0].tokens.clone()
    };
    assert_eq!(solo.len(), 6);
    for extra in 1..4usize {
        let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
        e.submit(Request::new(0, probe.clone(), 6));
        for j in 0..extra {
            e.submit(Request::new(1 + j as u64, vec![7 + j as i32, 9], 6));
        }
        let done = e.run_to_completion().expect("batched");
        let r0 = done.iter().find(|r| r.id == 0).expect("probe response");
        assert_eq!(r0.tokens, solo, "batch size {}", 1 + extra);
    }
}

#[test]
fn native_packed_and_direct_are_bit_exact() {
    let cfg = tiny_cfg(2);
    let mut direct = native_backend(cfg, WaqBackend::Direct);
    let mut packed = native_backend(cfg, WaqBackend::Packed);
    let prompt = vec![5i32, 9, 11, 2];

    let pd = direct.prefill(&prompt).expect("direct prefill");
    let pp = packed.prefill(&prompt).expect("packed prefill");
    assert_eq!(pd.plen, pp.plen);
    assert_eq!(pd.logits, pp.logits, "prefill logits must be bit-exact");
    assert_eq!(pd.k_cache, pp.k_cache);
    assert_eq!(pd.v_cache, pp.v_cache);

    let mut kv_d = KvManager::new(cfg);
    let mut kv_p = KvManager::new(cfg);
    kv_d.install_prefill(0, 1, pd.plen, &pd.k_cache, &pd.v_cache).unwrap();
    kv_p.install_prefill(0, 1, pp.plen, &pp.k_cache, &pp.v_cache).unwrap();
    let toks = [7i32, 0];
    let pos = [pd.plen as i32, 0];
    let act = [true, false];
    let (ld, _) = direct.decode(&toks, &pos, &act, &mut kv_d).expect("direct decode");
    let (lp, _) = packed.decode(&toks, &pos, &act, &mut kv_p).expect("packed decode");
    assert_eq!(ld, lp, "decode logits must be bit-exact");
    let (kd, vd) = kv_d.dense_tensors();
    let (kp, vp) = kv_p.dense_tensors();
    assert_eq!(kd, kp);
    assert_eq!(vd, vp);
}

/// The `--kv-bits 32` acceptance property: the paged FP32 cache feeds the
/// exact same attention arithmetic as the dense cache it replaced, so a
/// repeated decode is bit-identical — and the n-bit cache stays within a
/// quantization-error bound of it, tightening with bit-width. Uses the
/// same probe + error metric the kv_cache bench publishes
/// (`probe_decode_logits` / `rel_l2_err`), so the tested and benchmarked
/// numbers share one definition.
#[test]
fn kmeans_kv_cache_error_bounded_and_fp32_exact() {
    use kllm::coordinator::probe_decode_logits;
    use kllm::kvcache::KvPrecision;
    use kllm::util::stats::rel_l2_err;
    let cfg = tiny_cfg(2);
    let prompt = [5i32, 9, 11, 2, 30, 7];
    let mut backend = native_backend(cfg, WaqBackend::Packed);
    let fp_a =
        probe_decode_logits(&mut backend, KvPrecision::Fp32, &prompt, 7).expect("fp32 probe");
    let fp_b =
        probe_decode_logits(&mut backend, KvPrecision::Fp32, &prompt, 7).expect("fp32 probe");
    assert_eq!(fp_a, fp_b, "FP32 paged cache must be deterministic/bit-exact");

    // calibration-learned codebooks per (layer, head); looser bounds at
    // fewer bits — the point is "close", not "identical"
    for (bits, tol) in [(4u32, 0.35), (3, 0.5), (2, 0.8)] {
        let quant = KvPrecision::Quant(backend.kv_quantizer(bits));
        let logits =
            probe_decode_logits(&mut backend, quant, &prompt, 7).expect("quant probe");
        let e = rel_l2_err(&logits, &fp_a);
        assert!(e < tol, "{bits}-bit cache rel err {e} > {tol}");
        assert!(e > 0.0, "{bits}-bit cache unexpectedly bit-exact");
    }
}

/// Greedy decode must be deterministic across batch sizes at quantized
/// bit-widths too: a slot's rows are quantized from its own values with
/// fixed codebooks, so co-resident requests cannot perturb each other.
#[test]
fn quantized_kv_greedy_decode_deterministic_across_batch_sizes() {
    use kllm::kvcache::KvBits;
    // every supported quantized width (acceptance criterion), including
    // 3-bit — the one width whose codebook doesn't fill its nibble
    for kv_bits in [KvBits::B4, KvBits::B3, KvBits::B2] {
        let cfg = tiny_cfg(4);
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            kv_bits,
            ..Default::default()
        };
        let probe = vec![3i32, 14, 15];
        let solo = {
            let mut e =
                Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
            e.submit(Request::new(0, probe.clone(), 6));
            e.run_to_completion().expect("solo")[0].tokens.clone()
        };
        assert_eq!(solo.len(), 6);
        for extra in 1..4usize {
            let mut e =
                Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
            e.submit(Request::new(0, probe.clone(), 6));
            for j in 0..extra {
                e.submit(Request::new(1 + j as u64, vec![7 + j as i32, 9], 6));
            }
            let done = e.run_to_completion().expect("batched");
            let r0 = done.iter().find(|r| r.id == 0).expect("probe response");
            assert_eq!(r0.tokens, solo, "kv {kv_bits}-bit batch size {}", 1 + extra);
        }
    }
}

/// Serving with a 4-bit cache must stay cheap on the memory axis: the
/// engine's reported bytes/token is >= 4x below FP32's, and the peak
/// paged footprint tracks it.
#[test]
fn four_bit_cache_cuts_bytes_per_token_4x() {
    let cfg = tiny_cfg(2);
    let run = |kv_bits: kllm::kvcache::KvBits| {
        let ecfg = EngineConfig { kv_bits, ..Default::default() };
        let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
        e.submit(Request::new(1, vec![1, 2, 3], 6));
        e.run_to_completion().expect("run");
        (e.stats.kv_bytes_per_token, e.stats.peak_kv_bytes, e.stats.kv_bits)
    };
    let (fp_bpt, fp_peak, fp_bits) = run(kllm::kvcache::KvBits::Fp32);
    let (q_bpt, q_peak, q_bits) = run(kllm::kvcache::KvBits::B4);
    assert_eq!((fp_bits, q_bits), (32, 4));
    assert!(fp_bpt >= 4.0 * q_bpt, "bytes/token {q_bpt} not 4x under {fp_bpt}");
    assert!(q_peak > 0 && fp_peak > 0);
    assert!(fp_peak >= 4 * q_peak, "peak bytes {q_peak} not 4x under {fp_peak}");
}

#[test]
fn orizuru_outliers_route_through_compensation() {
    let cfg = tiny_cfg(2);
    let backend = native_backend(cfg, WaqBackend::Packed);
    let outliers = backend.outlier_counter();
    let mut e = Engine::new(Box::new(backend), &EngineConfig::default());
    e.submit(Request::new(1, vec![1, 2, 3], 5));
    let done = e.run_to_completion().expect("run");
    assert_eq!(done[0].tokens.len(), 5);
    // every online-quantized token detects >= 1 outlier per side, so the
    // compensation branch must have been exercised
    assert!(outliers.load(Ordering::Relaxed) > 0, "no outliers compensated");
}

#[test]
fn second_response_reports_its_own_modeled_energy() {
    // regression: Response.modeled_accel_j used to report the engine's
    // cumulative sim energy instead of the per-request delta
    let cfg = tiny_cfg(2);
    let mut e = Engine::new(Box::new(stub_backend(cfg)), &EngineConfig::default());
    e.submit(Request::new(1, vec![1, 2, 3], 4));
    let r1 = e.run_to_completion().expect("first").remove(0);
    e.submit(Request::new(2, vec![1, 2, 3], 4));
    let r2 = e.run_to_completion().expect("second").remove(0);
    assert!(r1.modeled_accel_j > 0.0 && r1.modeled_accel_s > 0.0);
    // identical workloads: the second response reports its own delta, not
    // the sum of both requests
    let ratio = r2.modeled_accel_j / r1.modeled_accel_j;
    assert!(ratio < 1.5, "cumulative energy leaked into response: ratio {ratio}");
    let sum = r1.modeled_accel_j + r2.modeled_accel_j;
    assert!(
        (sum - e.sim.energy_j).abs() <= 1e-9 * e.sim.energy_j,
        "per-request deltas {sum} should partition the total {}",
        e.sim.energy_j
    );
}

#[test]
fn aborted_inflight_requests_report_real_ttft() {
    let cfg = tiny_cfg(2);
    let mut e = Engine::new(Box::new(stub_backend(cfg)), &EngineConfig::default());
    e.submit(Request::new(1, vec![1, 2], 20));
    // one step = prefill (first token) + one decode step
    let done = e.step().expect("step");
    assert!(done.is_empty());
    let aborted = e.abort_all();
    assert_eq!(aborted.len(), 1);
    assert_eq!(aborted[0].finish_reason, FinishReason::Aborted);
    assert!(!aborted[0].tokens.is_empty());
    assert!(aborted[0].ttft_s > 0.0, "in-flight abort must report real TTFT");
    assert!(aborted[0].modeled_accel_s > 0.0);

    // queued-but-never-started requests still report zeros
    e.submit(Request::new(2, vec![1], 4));
    let queued = e.abort_all();
    assert_eq!(queued.len(), 1);
    assert!(queued[0].tokens.is_empty());
    assert_eq!(queued[0].ttft_s, 0.0);
}

#[test]
fn native_serving_through_coordinator_and_tcp() {
    use std::io::{BufRead, BufReader, Write};
    // NativeWaqBackend serves with no Runtime anywhere in the process: in
    // a default (featureless) build the PJRT stub's Runtime/Executable
    // constructors bail on first use, so completed generations are proof
    // the PJRT executables are never invoked in native mode.
    let cfg = tiny_cfg(2);
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let coord = Coordinator::start_with_manifest(
        manifest,
        params,
        EngineConfig {
            backend: BackendSpec::Native(WaqBackend::Packed),
            ..Default::default()
        },
    )
    .expect("native coordinator start");
    let r = coord.generate(vec![1, 2, 3], 5).expect("generate");
    assert_eq!(r.tokens.len(), 5);
    assert_eq!(r.finish_reason, FinishReason::MaxTokens);
    assert!(r.tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
    assert!(r.modeled_accel_s > 0.0 && r.modeled_accel_j > 0.0);
    let (stats, sim) = coord.stats().expect("stats");
    assert_eq!(stats.waq_backend, "native-packed");
    assert!(stats.host_waq_s > 0.0, "native host seconds are measured");
    assert!(sim.seconds > 0.0);

    // context exhaustion terminates on the native path too
    let long = coord.generate(vec![1; 8], cfg.seq_len * 4).expect("long");
    assert_eq!(long.finish_reason, FinishReason::Length);
    assert!(long.tokens.len() < cfg.seq_len * 4);

    // TCP front-end over the native engine
    let coord = std::sync::Arc::new(coord);
    let port = kllm::coordinator::serve_tcp(coord.clone(), 0).expect("tcp");
    let mut sock = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    sock.write_all(b"{\"prompt\": [4,5,6], \"max_new_tokens\": 4}\n")
        .unwrap();
    let mut line = String::new();
    BufReader::new(sock.try_clone().unwrap())
        .read_line(&mut line)
        .unwrap();
    let j = kllm::util::json::Json::parse(line.trim()).expect("json reply");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
}

// ---------------------------------------------------------------------------
// tensor-parallel sharded backend: parity net + concurrency stress
// ---------------------------------------------------------------------------

/// GEMM-level shard parity property: for random shapes (odd K, mixed
/// 4/3/2-bit activations x weights, outliers on/off, batch 1–16) and
/// shards in {1, 2, 3, 4, 7} — including uneven column splits where
/// `cols % shards != 0` and `cols < shards` — the sharded dual-branch
/// GEMM is bit-identical to the unsharded packed kernel + compensation.
#[test]
fn prop_sharded_gemm_bit_exact_for_any_split() {
    use kllm::gemm::{self, CartesianLut, ShardPool, ShardedWaqGemm};
    use kllm::quant::{self, OutlierCfg, QuantToken};
    use kllm::tensor::Matrix;
    use kllm::util::check::Check;
    use std::sync::Arc;

    Check::new(12).forall("sharded-gemm-bit-exact", |rng, case| {
        let k = 1 + rng.below(130); // odd and even K (odd: packed tail row)
        let n = 1 + rng.below(40); // incl. n < shards and n % shards != 0
        let a_bits = 2 + rng.below(3) as u32;
        let w_bits = 2 + rng.below(3) as u32;
        let batch = 1 + rng.below(16);
        let outliers_on = case % 2 == 0;
        let w = Matrix::random_normal(k, n, 1.0, rng);
        let qw = quant::quantize_weights(&w, w_bits);
        let calib: Vec<Vec<f32>> =
            (0..4).map(|_| rng.heavy_tailed_vec(k, 0.02, 8.0)).collect();
        let refs: Vec<&[f32]> = calib.iter().map(|v| v.as_slice()).collect();
        let ocfg = OutlierCfg { total_frac: 0.05 };
        let cb = quant::learn_act_codebook(&refs, None, a_bits, ocfg);
        let toks: Vec<QuantToken> = (0..batch)
            .map(|_| {
                let x = rng.heavy_tailed_vec(k, 0.02, 8.0);
                if outliers_on {
                    quant::quantize_token(&x, &cb, ocfg)
                } else {
                    quant::quantize_token_with_outliers(&x, &cb, &[])
                }
            })
            .collect();
        let lut = CartesianLut::build(&cb, &qw.codebook);
        let pw = qw.pack();
        let want: Vec<Vec<f32>> = toks
            .iter()
            .map(|t| {
                let mut o = gemm::execute_packed(t, &pw, &lut);
                gemm::compensate_packed(&mut o, t, &pw);
                o
            })
            .collect();
        for shards in [1usize, 2, 3, 4, 7] {
            let pool = Arc::new(ShardPool::new(shards).expect("pool"));
            let sh = ShardedWaqGemm::from_packed(&pw, &lut, shards, pool).expect("shard");
            assert_eq!(
                sh.execute_batch(&toks),
                want,
                "K={k} N={n} A{a_bits}/W{w_bits} batch={batch} shards={shards} \
                 outliers={outliers_on}"
            );
        }
    });
}

/// Backend-level shard parity: `native-sharded` logits are bit-identical
/// to `native-packed` at every shard count and every `--kv-bits` setting
/// (the acceptance property), prefill caches included. The tiny config's
/// linear widths (96/32/128) are not divisible by 7, so uneven backend
/// splits are exercised too.
#[test]
fn sharded_backend_bit_exact_with_native_packed_at_every_kv_bits() {
    use kllm::coordinator::probe_decode_logits;
    use kllm::kvcache::{KvBits, KvPrecision};
    let cfg = tiny_cfg(2);
    let prompt = vec![5i32, 9, 11, 2];
    let mut native = native_backend(cfg, WaqBackend::Packed);
    let pn = native.prefill(&prompt).expect("native prefill");
    for shards in [1usize, 2, 3, 4, 7] {
        let mut sh = sharded_backend(cfg, shards);
        assert_eq!(sh.spec().name(), "native-sharded");
        assert_eq!(sh.shard_count(), shards);
        let ps = sh.prefill(&prompt).expect("sharded prefill");
        assert_eq!(pn.plen, ps.plen);
        assert_eq!(pn.logits, ps.logits, "{shards}-shard prefill logits");
        assert_eq!(pn.k_cache, ps.k_cache);
        assert_eq!(pn.v_cache, ps.v_cache);
        for kv_bits in KvBits::ALL {
            let prec = |b: &mut dyn DecodeBackend| match kv_bits {
                KvBits::Fp32 => KvPrecision::Fp32,
                q => KvPrecision::Quant(b.kv_quantizer(q.bits())),
            };
            let pa = prec(&mut native);
            let a = probe_decode_logits(&mut native, pa, &prompt, 7).expect("native probe");
            let pb = prec(&mut sh);
            let b = probe_decode_logits(&mut sh, pb, &prompt, 7).expect("sharded probe");
            assert_eq!(a, b, "{shards} shards, kv {kv_bits}-bit decode logits");
        }
    }
}

/// The paged-allocator invariant block from `tests/properties.rs`, reused
/// against a live engine: no leaks, no double assignment, bounded tables.
fn check_paged_invariants(e: &Engine) {
    let kv = e.kv();
    let c = kv.cache();
    let cfg = &kv.cfg;
    let bt = c.block_tokens();
    let mut seen = std::collections::HashSet::new();
    let mut listed = 0usize;
    for slot in 0..cfg.decode_batch {
        for l in 0..cfg.n_layers {
            let written = c.written(l, slot);
            let blocks = c.slot_blocks(l, slot);
            assert!(written <= cfg.seq_len, "written out of bounds");
            assert_eq!(
                blocks.len(),
                written.div_ceil(bt),
                "table covers exactly the written positions"
            );
            if kv.position(slot).is_none() {
                assert_eq!(written, 0, "freed slot still has rows");
            }
            for &b in blocks {
                assert!((b as usize) < c.capacity_blocks(), "block id beyond pool");
                assert!(seen.insert(b), "block {b} assigned twice");
            }
            listed += blocks.len();
        }
    }
    assert_eq!(listed, c.in_use_blocks(), "block leak: listed != in-use");
}

/// Concurrency stress: one engine over the sharded backend, 8 requests
/// admitted in a single burst with a 4-bit KV cache. Per-request outputs
/// must be identical across two identical runs (co-resident requests and
/// shard workers cannot perturb each other), the paged-allocator
/// invariants must hold mid-flight, and `abort_all` must return every KV
/// block to the pool.
#[test]
fn sharded_engine_burst_is_deterministic_and_leak_free() {
    let cfg = tiny_cfg(8);
    let run = || {
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            backend: BackendSpec::NativeSharded,
            kv_bits: kllm::kvcache::KvBits::B4,
            shards: 3,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(sharded_backend(cfg, 3)), &ecfg);
        for id in 0..8u64 {
            e.submit(Request::new(id, vec![1 + id as i32, 2, 3], 5 + (id as usize % 3)));
        }
        let mut done = Vec::new();
        // burst admission (FillAll fills all 8 slots on the first step),
        // then a few decode steps: after 4 steps every request has 5
        // tokens, so the max_new=5 third completed and the rest are
        // mid-flight when we abort
        for _ in 0..4 {
            done.extend(e.step().expect("step"));
            check_paged_invariants(&e);
        }
        assert!(e.active_count() > 0, "burst should still be in flight");
        done.extend(e.abort_all());
        assert_eq!(e.active_count(), 0);
        assert_eq!(
            e.kv().cache().in_use_blocks(),
            0,
            "KV blocks leaked after abort_all"
        );
        assert!(e.stats.host_shard_crit_s > 0.0, "shard critical path not measured");
        assert_eq!(e.stats.waq_backend, "native-sharded");
        done.sort_by_key(|r| r.id);
        done.iter().map(|r| (r.id, r.tokens.clone())).collect::<Vec<_>>()
    };
    let a = run();
    let b = run();
    assert_eq!(a.len(), 8, "all 8 burst requests must be accounted for");
    assert_eq!(a, b, "two identical sharded runs must produce identical outputs");
}

// ---------------------------------------------------------------------------
// batched admission prefill: parity net + hardened admission path
// ---------------------------------------------------------------------------

/// Batched-vs-sequential prefill parity property (the acceptance
/// criterion): for random prompt lengths in 1..seq_len and burst sizes
/// 1..=8, `prefill_batch` must be bit-exact per request with the
/// sequential `prefill` path — logits AND K/V cache tensors — on both
/// native-packed and native-sharded, and the caches must land
/// bit-identically in the paged store at every `--kv-bits` setting
/// (FP32 and the 4/3/2-bit K-Means index streams alike).
#[test]
fn prop_batched_prefill_bit_exact_with_sequential_at_every_kv_bits() {
    use kllm::kvcache::{KvBits, KvPrecision};
    use kllm::util::check::Check;
    use std::cell::RefCell;

    let cfg = tiny_cfg(8);
    let backends: Vec<(&str, RefCell<Box<dyn DecodeBackend>>)> = vec![
        (
            "native-packed",
            RefCell::new(Box::new(native_backend(cfg, WaqBackend::Packed))),
        ),
        ("native-sharded", RefCell::new(Box::new(sharded_backend(cfg, 3)))),
    ];
    Check::new(8).forall("batched-prefill-parity", |rng, _case| {
        let burst = 1 + rng.below(8);
        let prompts: Vec<Vec<i32>> = (0..burst)
            .map(|_| {
                let plen = 1 + rng.below(cfg.seq_len - 1);
                (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect()
            })
            .collect();
        let refs: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
        for (name, cell) in &backends {
            let mut b = cell.borrow_mut();
            let seq: Vec<PrefillOut> =
                refs.iter().map(|p| b.prefill(p).expect("sequential prefill")).collect();
            let bat = b.prefill_batch(&refs).expect("batched prefill");
            assert_eq!(seq.len(), bat.len(), "{name}: one result per prompt");
            for (r, (a, c)) in seq.iter().zip(&bat).enumerate() {
                assert_eq!(a.plen, c.plen, "{name} burst={burst} request {r} plen");
                assert_eq!(a.logits, c.logits, "{name} burst={burst} request {r} logits");
                assert_eq!(a.k_cache, c.k_cache, "{name} burst={burst} request {r} K");
                assert_eq!(a.v_cache, c.v_cache, "{name} burst={burst} request {r} V");
            }
            // and the installed paged-cache contents agree at every
            // storage precision (quantized index streams included)
            for kv_bits in KvBits::ALL {
                let prec = |b: &mut dyn DecodeBackend| match kv_bits {
                    KvBits::Fp32 => KvPrecision::Fp32,
                    q => KvPrecision::Quant(b.kv_quantizer(q.bits())),
                };
                let mut kv_seq = KvManager::with_precision(cfg, prec(&mut **b));
                let mut kv_bat = KvManager::with_precision(cfg, prec(&mut **b));
                for (slot, (a, c)) in seq.iter().zip(&bat).enumerate() {
                    kv_seq
                        .install_prefill(slot, 1 + slot as u64, a.plen, &a.k_cache, &a.v_cache)
                        .expect("install sequential");
                    kv_bat
                        .install_prefill(slot, 1 + slot as u64, c.plen, &c.k_cache, &c.v_cache)
                        .expect("install batched");
                }
                assert_eq!(
                    kv_seq.dense_tensors(),
                    kv_bat.dense_tensors(),
                    "{name} burst={burst} paged cache at kv {kv_bits}-bit"
                );
            }
        }
    });
}

/// Backend whose prefill fails on a poisoned prompt token; everything
/// else delegates to the artifact-contract stub. Uses the trait's
/// *default* `prefill_batch`, so a poisoned prompt fails the burst
/// mid-loop — the exact shape of the old admission-path bug.
struct PoisonBackend {
    inner: PjrtBackend,
    poison: i32,
}

impl DecodeBackend for PoisonBackend {
    fn spec(&self) -> BackendSpec {
        self.inner.spec()
    }

    fn model(&self) -> kllm::runtime::artifacts::ModelCfg {
        self.inner.model()
    }

    fn prefill(&mut self, prompt: &[i32]) -> anyhow::Result<PrefillOut> {
        if prompt.contains(&self.poison) {
            anyhow::bail!("poisoned prompt");
        }
        self.inner.prefill(prompt)
    }

    fn decode(
        &mut self,
        toks: &[i32],
        pos: &[i32],
        active: &[bool],
        kv: &mut KvManager,
    ) -> anyhow::Result<(Vec<f32>, StepCost)> {
        self.inner.decode(toks, pos, active, kv)
    }
}

/// Regression (admission error path): a burst with one poisoned prompt
/// must never silently drop requests — before the fix, the failing
/// request and every later one popped by `Batcher::admit` vanished with
/// no `Response` and `Engine::step` returned `Err`. Now every admitted
/// request of the failed burst gets an `Aborted` response and the engine
/// keeps serving.
#[test]
fn burst_with_poisoned_prompt_never_drops_requests() {
    let cfg = tiny_cfg(4);
    let backend = PoisonBackend { inner: stub_backend(cfg), poison: -99 };
    let ecfg = EngineConfig { policy: AdmitPolicy::FillAll, ..Default::default() };
    let mut e = Engine::new(Box::new(backend), &ecfg);
    for id in 0..4u64 {
        let prompt = if id == 1 { vec![1, -99, 3] } else { vec![1 + id as i32, 2, 3] };
        e.submit(Request::new(id, prompt, 4));
    }
    let done = e.step().expect("a failed burst prefill must not error the step");
    assert_eq!(done.len(), 4, "every admitted request must get a Response");
    let mut ids: Vec<u64> = done.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, vec![0, 1, 2, 3], "no request may be silently dropped");
    for r in &done {
        assert_eq!(r.finish_reason, FinishReason::Aborted, "request {}", r.id);
        assert!(r.tokens.is_empty(), "request {}", r.id);
    }
    assert_eq!(e.stats.prefill_failures, 1);
    assert_eq!(e.active_count(), 0);
    assert_eq!(e.pending(), 0);
    assert_eq!(e.kv().cache().in_use_blocks(), 0, "failed burst must not leak KV blocks");

    // the engine keeps serving after the failure
    e.submit(Request::new(9, vec![1, 2], 3));
    let ok = e.run_to_completion().expect("clean request after failed burst");
    assert_eq!(ok.len(), 1);
    assert_eq!(ok[0].id, 9);
    assert_eq!(ok[0].finish_reason, FinishReason::MaxTokens);
    assert_eq!(ok[0].tokens.len(), 3);
}

/// Backend that records every `prefill_batch` arity (then delegates per
/// prompt to the stub): proves the engine hands a FillAll admit burst to
/// ONE batched-prefill call instead of looping `prefill` itself.
struct BurstProbe {
    inner: PjrtBackend,
    bursts: std::sync::Arc<std::sync::Mutex<Vec<usize>>>,
}

impl DecodeBackend for BurstProbe {
    fn spec(&self) -> BackendSpec {
        self.inner.spec()
    }

    fn model(&self) -> kllm::runtime::artifacts::ModelCfg {
        self.inner.model()
    }

    fn prefill(&mut self, prompt: &[i32]) -> anyhow::Result<PrefillOut> {
        self.inner.prefill(prompt)
    }

    fn prefill_batch(&mut self, prompts: &[&[i32]]) -> anyhow::Result<Vec<PrefillOut>> {
        self.bursts.lock().unwrap().push(prompts.len());
        prompts.iter().map(|p| self.inner.prefill(p)).collect()
    }

    fn decode(
        &mut self,
        toks: &[i32],
        pos: &[i32],
        active: &[bool],
        kv: &mut KvManager,
    ) -> anyhow::Result<(Vec<f32>, StepCost)> {
        self.inner.decode(toks, pos, active, kv)
    }
}

#[test]
fn engine_admits_whole_burst_through_one_prefill_batch_call() {
    let cfg = tiny_cfg(4);
    let bursts = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    let backend = BurstProbe { inner: stub_backend(cfg), bursts: bursts.clone() };
    let ecfg = EngineConfig { policy: AdmitPolicy::FillAll, ..Default::default() };
    let mut e = Engine::new(Box::new(backend), &ecfg);
    for id in 0..6u64 {
        e.submit(Request::new(id, vec![1 + id as i32, 2], 3));
    }
    e.run_to_completion().expect("run");
    let bursts = bursts.lock().unwrap();
    assert_eq!(bursts[0], 4, "FillAll fills all four free slots via ONE prefill_batch");
    assert!(bursts.iter().all(|&n| n >= 1), "empty bursts must not reach the backend");
    assert_eq!(bursts.iter().sum::<usize>(), 6, "every request prefilled exactly once");
}

/// Silent-truncation regression: a prompt longer than the context window
/// is clamped by the backend; the response must say so instead of
/// pretending the full context was consumed.
#[test]
fn over_long_prompt_surfaces_truncation() {
    let cfg = tiny_cfg(2);
    let mut e =
        Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &EngineConfig::default());
    // seq_len + 7 tokens into a seq_len window (the issue's probe length)
    let long = vec![7i32; cfg.seq_len + 7];
    e.submit(Request::new(1, long, 2));
    e.submit(Request::new(2, vec![1, 2, 3], 2));
    let mut done = e.run_to_completion().expect("run");
    done.sort_by_key(|r| r.id);
    assert_eq!(done.len(), 2);
    assert_eq!(done[0].prompt_len, cfg.seq_len + 7, "reports the *submitted* length");
    assert!(done[0].truncated_prompt, "clamped prompt must be surfaced");
    assert!(!done[1].truncated_prompt, "in-window prompt is not flagged");
    assert_eq!(e.stats.truncated_prompts, 1);
}

/// Tentpole acceptance: decode after a prefix-cache HIT is bit-exact
/// with a cold run at every `--kv-bits`. Shared blocks keep their
/// quantized payloads, so the hit path reads exactly the bytes the cold
/// path would have written — greedy token streams must be identical.
#[test]
fn prefix_hit_decode_bit_exact_with_cold_at_every_kv_bits() {
    use kllm::kvcache::KvBits;
    // seq_len 48 → three 16-token blocks per slot: the 20-token probe
    // spans one full shared block plus a partial chunk, so the warm run
    // exercises both exact-block aliasing and partial-chunk matching.
    let cfg = ModelCfg { seq_len: 48, ..tiny_cfg(2) };
    let shared: Vec<i32> = (0..20).map(|i| 5 + i as i32).collect();
    for kv_bits in KvBits::ALL {
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            kv_bits,
            prefix_cache: true,
            ..Default::default()
        };
        // cold: fresh engine, empty index — the whole prompt is computed
        let cold = {
            let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
            e.submit(Request::new(0, shared.clone(), 6));
            let done = e.run_to_completion().expect("cold");
            assert_eq!(e.stats.prefix_hits, 0, "{kv_bits:?}");
            done[0].tokens.clone()
        };
        assert_eq!(cold.len(), 6, "{kv_bits:?}");
        // warm: prime the index with the same prompt, then re-serve it —
        // the probe aliases every cached block and computes one token
        let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
        e.submit(Request::new(0, shared.clone(), 6));
        e.run_to_completion().expect("prime");
        e.submit(Request::new(1, shared.clone(), 6));
        let done = e.run_to_completion().expect("warm");
        assert_eq!(e.stats.prefix_hits, 1, "{kv_bits:?}");
        assert!(
            e.stats.prefix_blocks_reused >= cfg.n_layers as u64,
            "{kv_bits:?}: reused {}",
            e.stats.prefix_blocks_reused
        );
        assert_eq!(done[0].tokens, cold, "prefix-hit decode diverged at {kv_bits:?}");
        // slots drained → every live block is parked in the prefix index
        assert!(e.kv().cache().prefix_nodes() > 0, "{kv_bits:?}");
        assert!(e.kv().cache().in_use_blocks() > 0, "{kv_bits:?}");
    }
}

/// At fp32 the paged prefill path (`--prefix-cache on`, cache-mediated
/// attention) is bit-exact with the legacy dense prefill path
/// (`--prefix-cache off`) — same float ops in the same order.
#[test]
fn paged_prefill_matches_legacy_dense_prefill_at_fp32() {
    let cfg = ModelCfg { seq_len: 48, ..tiny_cfg(2) };
    let run = |prefix_cache: bool| {
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            prefix_cache,
            ..Default::default()
        };
        let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
        e.submit(Request::new(0, (0..20).map(|i| 5 + i as i32).collect(), 6));
        e.submit(Request::new(1, vec![3, 14, 15], 6));
        let mut done = e.run_to_completion().expect("run");
        done.sort_by_key(|r| r.id);
        done.into_iter().map(|r| r.tokens).collect::<Vec<_>>()
    };
    assert_eq!(run(true), run(false), "paged vs legacy dense prefill tokens");
}

// ---------------------------------------------------------------------------
// speculative decoding: greedy-parity net (the subsystem's acceptance bar)
// ---------------------------------------------------------------------------

/// Synthetic params with each layer's residual contributions damped, so
/// the greedy argmax develops real margins and speculative rounds accept
/// proposals. Parity must hold at *any* acceptance rate; damping makes
/// the accept/commit paths (not just rejection + rollback) do real work
/// in these tests.
fn damped_params(manifest: &Manifest, damp: f32) -> ParamSet {
    let mut params = ParamSet::init(manifest, &mut Rng::new(42));
    for l in 0..manifest.model.n_layers {
        for name in [format!("l{l}.attn_out"), format!("l{l}.mlp_down")] {
            let idx = ParamSet::index_of(manifest, &name).expect("manifest param");
            let mut m = params.matrix(idx).expect("matrix");
            for v in m.data.iter_mut() {
                *v *= damp;
            }
            params.set_matrix(idx, &m).expect("set matrix");
        }
    }
    params
}

/// Seeded 4-request stream; returns `(id, tokens)` sorted by id.
fn spec_stream(e: &mut Engine, vocab: usize) -> Vec<(u64, Vec<i32>)> {
    let mut rng = Rng::new(9);
    for id in 0..4u64 {
        let plen = 1 + rng.below(5);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        e.submit(Request::new(id, prompt, 6));
    }
    let mut out = e.run_to_completion().expect("run");
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| (r.id, r.tokens)).collect()
}

/// Tentpole acceptance: `--backend native-spec` is bit-exact with the
/// target alone — same greedy token streams — across every `--kv-bits`
/// setting, `--spec-k` in {1, 2, 4}, and `--prefix-cache` off/on. The
/// draft only ever *proposes*; every emitted token comes from the
/// target's own logits, so acceptance (high here by construction) and
/// rejection-rollback alike must leave the streams untouched.
#[test]
fn speculative_bit_exact_with_target_at_every_kv_bits_k_and_prefix() {
    use kllm::coordinator::SpeculativeBackend;
    use kllm::kvcache::KvBits;
    let cfg = tiny_cfg(2);
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = damped_params(&manifest, 0.05);
    let ncfg = || NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() };
    for kv_bits in KvBits::ALL {
        for prefix_cache in [false, true] {
            let ecfg = EngineConfig {
                policy: AdmitPolicy::FillAll,
                kv_bits,
                prefix_cache,
                ..Default::default()
            };
            let want = {
                let target = NativeWaqBackend::new(&manifest, &params, ncfg()).expect("target");
                let mut e = Engine::new(Box::new(target), &ecfg);
                spec_stream(&mut e, cfg.vocab)
            };
            for k in [1usize, 2, 4] {
                let ecfg = EngineConfig {
                    backend: BackendSpec::NativeSpec,
                    spec_k: k,
                    draft_wbits: 2,
                    ..ecfg.clone()
                };
                let target = NativeWaqBackend::new(&manifest, &params, ncfg()).expect("target");
                let spec = SpeculativeBackend::new(
                    &manifest,
                    &params,
                    Box::new(target),
                    ecfg.mode,
                    k,
                    2,
                )
                .expect("speculative backend");
                let mut e = Engine::new(Box::new(spec), &ecfg);
                let got = spec_stream(&mut e, cfg.vocab);
                assert_eq!(
                    got, want,
                    "kv {kv_bits}-bit prefix={prefix_cache} k={k}: speculative \
                     streams diverged from the target's"
                );
                assert!(e.stats.spec_rounds > 0, "no speculative rounds ran");
                assert!(
                    e.stats.spec_proposed >= e.stats.spec_accepted,
                    "accepted {} > proposed {}",
                    e.stats.spec_accepted,
                    e.stats.spec_proposed
                );
                assert_eq!(e.stats.step_failures, 0);
                assert_eq!(e.active_count(), 0);
            }
        }
    }
}

/// The same parity bar with a tensor-parallel sharded target: the
/// composite's verify path rides the sharded backend's paged surface
/// (which must agree bit-for-bit with unsharded packed, per the shard
/// parity net above), so the speculative streams still match a plain
/// native-packed engine's.
#[test]
fn speculative_over_sharded_target_bit_exact() {
    use kllm::coordinator::SpeculativeBackend;
    let cfg = tiny_cfg(2);
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = damped_params(&manifest, 0.05);
    let ecfg = EngineConfig {
        policy: AdmitPolicy::FillAll,
        kv_bits: kllm::kvcache::KvBits::B4,
        ..Default::default()
    };
    let want = {
        let target = NativeWaqBackend::new(
            &manifest,
            &params,
            NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() },
        )
        .expect("target");
        let mut e = Engine::new(Box::new(target), &ecfg);
        spec_stream(&mut e, cfg.vocab)
    };
    let ecfg = EngineConfig {
        backend: BackendSpec::NativeSpec,
        spec_k: 3,
        draft_wbits: 3,
        shards: 3,
        ..ecfg
    };
    let target =
        ShardedWaqBackend::new(&manifest, &params, NativeCfg::default(), 3).expect("sharded");
    let spec =
        SpeculativeBackend::new(&manifest, &params, Box::new(target), ecfg.mode, 3, 3)
            .expect("speculative backend");
    let mut e = Engine::new(Box::new(spec), &ecfg);
    let got = spec_stream(&mut e, cfg.vocab);
    assert_eq!(got, want, "sharded-target speculative streams diverged");
    assert!(e.stats.spec_rounds > 0);
}

/// `--shards 0` is a configuration error with a real message, never a
/// panic — at the pool, the GEMM, and the backend layer.
#[test]
fn zero_shards_rejected_with_real_error() {
    let err = match kllm::gemm::ShardPool::new(0) {
        Err(e) => e,
        Ok(_) => panic!("0-worker pool must fail"),
    };
    assert!(err.contains("--shards 0"), "{err}");
    let cfg = tiny_cfg(2);
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let err = match ShardedWaqBackend::new(&manifest, &params, NativeCfg::default(), 0) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("0 shards must fail"),
    };
    assert!(err.contains("--shards 0"), "{err}");
}
