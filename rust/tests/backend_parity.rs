//! Backend-parity and native-datapath integration tests. Everything here
//! runs in the default (featureless) build: the PJRT side uses
//! `PjrtBackend::stub` (the artifact-contract test double) and the native
//! side needs no artifacts at all (`Manifest::synthetic`).

use std::sync::atomic::Ordering;

use kllm::coordinator::{
    AdmitPolicy, BackendSpec, Coordinator, DecodeBackend, Engine, EngineConfig,
    FinishReason, KvManager, NativeCfg, NativeWaqBackend, PjrtBackend, Request, Response,
};
use kllm::gemm::WaqBackend;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::sim::OasisMode;
use kllm::util::rng::Rng;

fn tiny_cfg(decode_batch: usize) -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        seq_len: 16,
        batch: 1,
        decode_batch,
        head_dim: 16,
        d_ff: 128,
        n_linears: 8,
    }
}

fn native_backend(cfg: ModelCfg, waq: WaqBackend) -> NativeWaqBackend {
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    NativeWaqBackend::new(&manifest, &params, NativeCfg { waq, ..NativeCfg::default() })
        .expect("native backend build")
}

fn stub_backend(cfg: ModelCfg) -> PjrtBackend {
    PjrtBackend::stub(cfg, WaqBackend::Packed, OasisMode::a4())
}

/// Submit the same seeded request stream and drain the engine.
fn run_stream(engine: &mut Engine, vocab: usize) -> Vec<Response> {
    let mut rng = Rng::new(9);
    for id in 0..6u64 {
        let plen = 1 + rng.below(5);
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        engine.submit(Request::new(id, prompt, 3 + rng.below(4)));
    }
    let mut out = engine.run_to_completion().expect("run");
    out.sort_by_key(|r| r.id);
    out
}

#[test]
fn stub_and_native_drive_identical_engine_bookkeeping() {
    let cfg = tiny_cfg(2);
    let ecfg = EngineConfig::default();
    let mut stub = Engine::new(Box::new(stub_backend(cfg)), &ecfg);
    let mut native = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
    let a = run_stream(&mut stub, cfg.vocab);
    let b = run_stream(&mut native, cfg.vocab);

    // token *values* differ (different logits); the engine bookkeeping —
    // admission order, slot lifecycle, finish reasons, token counts —
    // must be identical for the same request stream
    assert_eq!(a.len(), b.len());
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.id, rb.id);
        assert_eq!(ra.prompt_len, rb.prompt_len);
        assert_eq!(ra.tokens.len(), rb.tokens.len(), "request {}", ra.id);
        assert_eq!(ra.finish_reason, rb.finish_reason, "request {}", ra.id);
    }
    assert_eq!(stub.stats.prefills, native.stats.prefills);
    assert_eq!(stub.stats.decode_steps, native.stats.decode_steps);
    assert_eq!(stub.stats.generated_tokens, native.stats.generated_tokens);
    assert_eq!(stub.stats.occupancy_sum, native.stats.occupancy_sum);
    assert_eq!(stub.stats.completed, native.stats.completed);
    assert_eq!(stub.active_count(), 0);
    assert_eq!(native.active_count(), 0);
    // same modeled accelerator work, different host-clock semantics
    assert!((stub.sim.seconds - native.sim.seconds).abs() < 1e-12);
    assert_eq!(stub.stats.waq_backend, "packed");
    assert_eq!(native.stats.waq_backend, "native-packed");
}

#[test]
fn native_greedy_decode_deterministic_across_batch_sizes() {
    let cfg = tiny_cfg(4);
    let ecfg = EngineConfig { policy: AdmitPolicy::FillAll, ..Default::default() };
    let probe = vec![3i32, 14, 15];
    let solo = {
        let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
        e.submit(Request::new(0, probe.clone(), 6));
        e.run_to_completion().expect("solo")[0].tokens.clone()
    };
    assert_eq!(solo.len(), 6);
    for extra in 1..4usize {
        let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
        e.submit(Request::new(0, probe.clone(), 6));
        for j in 0..extra {
            e.submit(Request::new(1 + j as u64, vec![7 + j as i32, 9], 6));
        }
        let done = e.run_to_completion().expect("batched");
        let r0 = done.iter().find(|r| r.id == 0).expect("probe response");
        assert_eq!(r0.tokens, solo, "batch size {}", 1 + extra);
    }
}

#[test]
fn native_packed_and_direct_are_bit_exact() {
    let cfg = tiny_cfg(2);
    let mut direct = native_backend(cfg, WaqBackend::Direct);
    let mut packed = native_backend(cfg, WaqBackend::Packed);
    let prompt = vec![5i32, 9, 11, 2];

    let pd = direct.prefill(&prompt).expect("direct prefill");
    let pp = packed.prefill(&prompt).expect("packed prefill");
    assert_eq!(pd.plen, pp.plen);
    assert_eq!(pd.logits, pp.logits, "prefill logits must be bit-exact");
    assert_eq!(pd.k_cache, pp.k_cache);
    assert_eq!(pd.v_cache, pp.v_cache);

    let mut kv_d = KvManager::new(cfg);
    let mut kv_p = KvManager::new(cfg);
    kv_d.install_prefill(0, 1, pd.plen, &pd.k_cache, &pd.v_cache).unwrap();
    kv_p.install_prefill(0, 1, pp.plen, &pp.k_cache, &pp.v_cache).unwrap();
    let toks = [7i32, 0];
    let pos = [pd.plen as i32, 0];
    let act = [true, false];
    let (ld, _) = direct.decode(&toks, &pos, &act, &mut kv_d).expect("direct decode");
    let (lp, _) = packed.decode(&toks, &pos, &act, &mut kv_p).expect("packed decode");
    assert_eq!(ld, lp, "decode logits must be bit-exact");
    let (kd, vd) = kv_d.dense_tensors();
    let (kp, vp) = kv_p.dense_tensors();
    assert_eq!(kd, kp);
    assert_eq!(vd, vp);
}

/// The `--kv-bits 32` acceptance property: the paged FP32 cache feeds the
/// exact same attention arithmetic as the dense cache it replaced, so a
/// repeated decode is bit-identical — and the n-bit cache stays within a
/// quantization-error bound of it, tightening with bit-width. Uses the
/// same probe + error metric the kv_cache bench publishes
/// (`probe_decode_logits` / `rel_l2_err`), so the tested and benchmarked
/// numbers share one definition.
#[test]
fn kmeans_kv_cache_error_bounded_and_fp32_exact() {
    use kllm::coordinator::probe_decode_logits;
    use kllm::kvcache::KvPrecision;
    use kllm::util::stats::rel_l2_err;
    let cfg = tiny_cfg(2);
    let prompt = [5i32, 9, 11, 2, 30, 7];
    let mut backend = native_backend(cfg, WaqBackend::Packed);
    let fp_a =
        probe_decode_logits(&mut backend, KvPrecision::Fp32, &prompt, 7).expect("fp32 probe");
    let fp_b =
        probe_decode_logits(&mut backend, KvPrecision::Fp32, &prompt, 7).expect("fp32 probe");
    assert_eq!(fp_a, fp_b, "FP32 paged cache must be deterministic/bit-exact");

    // calibration-learned codebooks per (layer, head); looser bounds at
    // fewer bits — the point is "close", not "identical"
    for (bits, tol) in [(4u32, 0.35), (3, 0.5), (2, 0.8)] {
        let quant = KvPrecision::Quant(backend.kv_quantizer(bits));
        let logits =
            probe_decode_logits(&mut backend, quant, &prompt, 7).expect("quant probe");
        let e = rel_l2_err(&logits, &fp_a);
        assert!(e < tol, "{bits}-bit cache rel err {e} > {tol}");
        assert!(e > 0.0, "{bits}-bit cache unexpectedly bit-exact");
    }
}

/// Greedy decode must be deterministic across batch sizes at quantized
/// bit-widths too: a slot's rows are quantized from its own values with
/// fixed codebooks, so co-resident requests cannot perturb each other.
#[test]
fn quantized_kv_greedy_decode_deterministic_across_batch_sizes() {
    use kllm::kvcache::KvBits;
    // every supported quantized width (acceptance criterion), including
    // 3-bit — the one width whose codebook doesn't fill its nibble
    for kv_bits in [KvBits::B4, KvBits::B3, KvBits::B2] {
        let cfg = tiny_cfg(4);
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            kv_bits,
            ..Default::default()
        };
        let probe = vec![3i32, 14, 15];
        let solo = {
            let mut e =
                Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
            e.submit(Request::new(0, probe.clone(), 6));
            e.run_to_completion().expect("solo")[0].tokens.clone()
        };
        assert_eq!(solo.len(), 6);
        for extra in 1..4usize {
            let mut e =
                Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
            e.submit(Request::new(0, probe.clone(), 6));
            for j in 0..extra {
                e.submit(Request::new(1 + j as u64, vec![7 + j as i32, 9], 6));
            }
            let done = e.run_to_completion().expect("batched");
            let r0 = done.iter().find(|r| r.id == 0).expect("probe response");
            assert_eq!(r0.tokens, solo, "kv {kv_bits}-bit batch size {}", 1 + extra);
        }
    }
}

/// Serving with a 4-bit cache must stay cheap on the memory axis: the
/// engine's reported bytes/token is >= 4x below FP32's, and the peak
/// paged footprint tracks it.
#[test]
fn four_bit_cache_cuts_bytes_per_token_4x() {
    let cfg = tiny_cfg(2);
    let run = |kv_bits: kllm::kvcache::KvBits| {
        let ecfg = EngineConfig { kv_bits, ..Default::default() };
        let mut e = Engine::new(Box::new(native_backend(cfg, WaqBackend::Packed)), &ecfg);
        e.submit(Request::new(1, vec![1, 2, 3], 6));
        e.run_to_completion().expect("run");
        (e.stats.kv_bytes_per_token, e.stats.peak_kv_bytes, e.stats.kv_bits)
    };
    let (fp_bpt, fp_peak, fp_bits) = run(kllm::kvcache::KvBits::Fp32);
    let (q_bpt, q_peak, q_bits) = run(kllm::kvcache::KvBits::B4);
    assert_eq!((fp_bits, q_bits), (32, 4));
    assert!(fp_bpt >= 4.0 * q_bpt, "bytes/token {q_bpt} not 4x under {fp_bpt}");
    assert!(q_peak > 0 && fp_peak > 0);
    assert!(fp_peak >= 4 * q_peak, "peak bytes {q_peak} not 4x under {fp_peak}");
}

#[test]
fn orizuru_outliers_route_through_compensation() {
    let cfg = tiny_cfg(2);
    let backend = native_backend(cfg, WaqBackend::Packed);
    let outliers = backend.outlier_counter();
    let mut e = Engine::new(Box::new(backend), &EngineConfig::default());
    e.submit(Request::new(1, vec![1, 2, 3], 5));
    let done = e.run_to_completion().expect("run");
    assert_eq!(done[0].tokens.len(), 5);
    // every online-quantized token detects >= 1 outlier per side, so the
    // compensation branch must have been exercised
    assert!(outliers.load(Ordering::Relaxed) > 0, "no outliers compensated");
}

#[test]
fn second_response_reports_its_own_modeled_energy() {
    // regression: Response.modeled_accel_j used to report the engine's
    // cumulative sim energy instead of the per-request delta
    let cfg = tiny_cfg(2);
    let mut e = Engine::new(Box::new(stub_backend(cfg)), &EngineConfig::default());
    e.submit(Request::new(1, vec![1, 2, 3], 4));
    let r1 = e.run_to_completion().expect("first").remove(0);
    e.submit(Request::new(2, vec![1, 2, 3], 4));
    let r2 = e.run_to_completion().expect("second").remove(0);
    assert!(r1.modeled_accel_j > 0.0 && r1.modeled_accel_s > 0.0);
    // identical workloads: the second response reports its own delta, not
    // the sum of both requests
    let ratio = r2.modeled_accel_j / r1.modeled_accel_j;
    assert!(ratio < 1.5, "cumulative energy leaked into response: ratio {ratio}");
    let sum = r1.modeled_accel_j + r2.modeled_accel_j;
    assert!(
        (sum - e.sim.energy_j).abs() <= 1e-9 * e.sim.energy_j,
        "per-request deltas {sum} should partition the total {}",
        e.sim.energy_j
    );
}

#[test]
fn aborted_inflight_requests_report_real_ttft() {
    let cfg = tiny_cfg(2);
    let mut e = Engine::new(Box::new(stub_backend(cfg)), &EngineConfig::default());
    e.submit(Request::new(1, vec![1, 2], 20));
    // one step = prefill (first token) + one decode step
    let done = e.step().expect("step");
    assert!(done.is_empty());
    let aborted = e.abort_all();
    assert_eq!(aborted.len(), 1);
    assert_eq!(aborted[0].finish_reason, FinishReason::Aborted);
    assert!(!aborted[0].tokens.is_empty());
    assert!(aborted[0].ttft_s > 0.0, "in-flight abort must report real TTFT");
    assert!(aborted[0].modeled_accel_s > 0.0);

    // queued-but-never-started requests still report zeros
    e.submit(Request::new(2, vec![1], 4));
    let queued = e.abort_all();
    assert_eq!(queued.len(), 1);
    assert!(queued[0].tokens.is_empty());
    assert_eq!(queued[0].ttft_s, 0.0);
}

#[test]
fn native_serving_through_coordinator_and_tcp() {
    use std::io::{BufRead, BufReader, Write};
    // NativeWaqBackend serves with no Runtime anywhere in the process: in
    // a default (featureless) build the PJRT stub's Runtime/Executable
    // constructors bail on first use, so completed generations are proof
    // the PJRT executables are never invoked in native mode.
    let cfg = tiny_cfg(2);
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let coord = Coordinator::start_with_manifest(
        manifest,
        params,
        EngineConfig {
            backend: BackendSpec::Native(WaqBackend::Packed),
            ..Default::default()
        },
    )
    .expect("native coordinator start");
    let r = coord.generate(vec![1, 2, 3], 5).expect("generate");
    assert_eq!(r.tokens.len(), 5);
    assert_eq!(r.finish_reason, FinishReason::MaxTokens);
    assert!(r.tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
    assert!(r.modeled_accel_s > 0.0 && r.modeled_accel_j > 0.0);
    let (stats, sim) = coord.stats().expect("stats");
    assert_eq!(stats.waq_backend, "native-packed");
    assert!(stats.host_waq_s > 0.0, "native host seconds are measured");
    assert!(sim.seconds > 0.0);

    // context exhaustion terminates on the native path too
    let long = coord.generate(vec![1; 8], cfg.seq_len * 4).expect("long");
    assert_eq!(long.finish_reason, FinishReason::Length);
    assert!(long.tokens.len() < cfg.seq_len * 4);

    // TCP front-end over the native engine
    let coord = std::sync::Arc::new(coord);
    let port = kllm::coordinator::serve_tcp(coord.clone(), 0).expect("tcp");
    let mut sock = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    sock.write_all(b"{\"prompt\": [4,5,6], \"max_new_tokens\": 4}\n")
        .unwrap();
    let mut line = String::new();
    BufReader::new(sock.try_clone().unwrap())
        .read_line(&mut line)
        .unwrap();
    let j = kllm::util::json::Json::parse(line.trim()).expect("json reply");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
}
