//! Integration: the full serving stack (coordinator thread + engine +
//! batcher + KV manager + PJRT decode) over the `test` preset artifacts.
//! Skips (with a note) when the `pjrt` feature is off or artifacts are
//! missing, so the offline tier-1 suite stays green.

use std::sync::Arc;

use kllm::coordinator::{AdmitPolicy, Coordinator, EngineConfig, FinishReason};
use kllm::runtime::{artifacts_dir, pjrt_available, Manifest, ParamSet};
use kllm::util::rng::Rng;

fn params() -> Option<(ParamSet, kllm::runtime::artifacts::ModelCfg)> {
    if !pjrt_available() {
        eprintln!("skipping: kllm built without the `pjrt` feature");
        return None;
    }
    let dir = artifacts_dir("test");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/test missing — run `make artifacts` first");
        return None;
    }
    let m = Manifest::load(&dir).unwrap();
    Some((ParamSet::init(&m, &mut Rng::new(42)), m.model))
}

fn start() -> Option<(Coordinator, kllm::runtime::artifacts::ModelCfg)> {
    let (p, cfg) = params()?;
    Some((
        Coordinator::start("test".into(), p, EngineConfig::default()).expect("start"),
        cfg,
    ))
}

/// Always-on (no PJRT, no artifacts): the coordinator's startup error path
/// must surface the engine-thread failure synchronously with a message
/// that names the missing capability, not hang or panic. This keeps the
/// Coordinator/engine glue exercised even when every other test here
/// skips in an offline build.
#[cfg(not(feature = "pjrt"))]
#[test]
fn startup_without_pjrt_fails_fast_with_clear_error() {
    let manifest_text = r#"{
      "preset":"t","config":{"vocab":16,"d_model":8,"n_layers":1,
        "n_heads":2,"seq_len":4,"batch":1,"decode_batch":1,"head_dim":4,
        "d_ff":32,"n_linears":4},
      "params":[{"name":"tok_emb","shape":[16,8]}],
      "artifacts":{}
    }"#;
    let m = Manifest::parse(std::path::Path::new("/tmp"), manifest_text).unwrap();
    let params = ParamSet::init(&m, &mut Rng::new(1));
    let err = Coordinator::start("definitely-missing-preset".into(), params, EngineConfig::default())
        .err()
        .expect("start must fail without the pjrt feature");
    assert!(err.to_string().contains("pjrt"), "unhelpful error: {err}");
}

#[test]
fn single_request_roundtrip() {
    let Some((coord, cfg)) = start() else { return };
    let resp = coord.generate(vec![1, 2, 3, 4], 6).expect("generate");
    assert_eq!(resp.tokens.len(), 6);
    assert_eq!(resp.finish_reason, FinishReason::MaxTokens);
    assert!(resp.tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
    assert!(resp.ttft_s > 0.0 && resp.total_s >= resp.ttft_s);
    assert!(resp.modeled_accel_s > 0.0);
    coord.shutdown().unwrap();
}

#[test]
fn batched_requests_all_complete() {
    let Some((coord, cfg)) = start() else { return };
    let mut rxs = Vec::new();
    let mut rng = Rng::new(7);
    for i in 0..6 {
        let prompt: Vec<i32> = (0..3 + i % 4)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        let (_id, rx) = coord.submit_async(prompt, 5, 0.0).unwrap();
        rxs.push(rx);
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().expect("response");
        assert_eq!(r.tokens.len(), 5, "request {i}");
    }
    let (stats, sim) = coord.stats().unwrap();
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.prefills, 6);
    // continuous batching actually batched: fewer decode steps than
    // 6 requests x 4 decode tokens (= 24 sequential steps)
    assert!(stats.decode_steps < 24, "decode_steps {}", stats.decode_steps);
    assert!(stats.mean_occupancy() > 1.0, "occupancy {}", stats.mean_occupancy());
    assert!(sim.seconds > 0.0 && sim.energy_j > 0.0);
    coord.shutdown().unwrap();
}

#[test]
fn deterministic_greedy_decoding() {
    let Some((coord, _)) = start() else { return };
    let a = coord.generate(vec![5, 6, 7], 8).unwrap();
    let b = coord.generate(vec![5, 6, 7], 8).unwrap();
    assert_eq!(a.tokens, b.tokens);
    coord.shutdown().unwrap();

    // same prompt through a fresh coordinator with identical weights
    let Some((coord2, _)) = start() else { return };
    let c = coord2.generate(vec![5, 6, 7], 8).unwrap();
    assert_eq!(a.tokens, c.tokens);
    coord2.shutdown().unwrap();
}

#[test]
fn context_exhaustion_terminates() {
    let Some((coord, cfg)) = start() else { return };
    // ask for far more tokens than the context window holds
    let resp = coord
        .generate(vec![1; cfg.seq_len / 2], cfg.seq_len * 4)
        .unwrap();
    assert_eq!(resp.finish_reason, FinishReason::Length);
    assert!(resp.tokens.len() < cfg.seq_len * 4);
    coord.shutdown().unwrap();
}

#[test]
fn fill_all_policy_works() {
    let Some((p, _)) = params() else { return };
    let coord = Coordinator::start(
        "test".into(),
        p,
        EngineConfig { policy: AdmitPolicy::FillAll, ..Default::default() },
    )
    .unwrap();
    let mut rxs = Vec::new();
    for _ in 0..4 {
        rxs.push(coord.submit_async(vec![9, 9], 4, 0.0).unwrap().1);
    }
    for rx in rxs {
        assert_eq!(rx.recv().unwrap().tokens.len(), 4);
    }
    coord.shutdown().unwrap();
}

#[test]
fn tcp_front_end_roundtrip() {
    use std::io::{BufRead, BufReader, Write};
    let Some((p, _)) = params() else { return };
    let coord = Arc::new(
        Coordinator::start("test".into(), p, EngineConfig::default()).unwrap(),
    );
    let port = kllm::coordinator::serve_tcp(coord.clone(), 0).expect("tcp");
    let mut sock = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    sock.write_all(b"{\"prompt\": [1,2,3], \"max_new_tokens\": 4}\n")
        .unwrap();
    let mut line = String::new();
    BufReader::new(sock.try_clone().unwrap())
        .read_line(&mut line)
        .unwrap();
    let j = kllm::util::json::Json::parse(line.trim()).expect("json reply");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    // malformed request gets an error object, not a hang
    sock.write_all(b"{\"nope\": 1}\n").unwrap();
    let mut line2 = String::new();
    BufReader::new(sock.try_clone().unwrap())
        .read_line(&mut line2)
        .unwrap();
    assert!(line2.contains("error"));
}
