//! Integration: the full serving stack (coordinator thread + engine +
//! batcher + KV manager + PJRT decode) over the `test` preset artifacts.

use std::sync::Arc;

use kllm::coordinator::{AdmitPolicy, Coordinator, EngineConfig, FinishReason};
use kllm::runtime::{artifacts_dir, Manifest, ParamSet};
use kllm::util::rng::Rng;

fn params() -> (ParamSet, kllm::runtime::artifacts::ModelCfg) {
    let dir = artifacts_dir("test");
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts/test missing — run `make artifacts` first"
    );
    let m = Manifest::load(&dir).unwrap();
    (ParamSet::init(&m, &mut Rng::new(42)), m.model)
}

fn start() -> (Coordinator, kllm::runtime::artifacts::ModelCfg) {
    let (p, cfg) = params();
    (
        Coordinator::start("test".into(), p, EngineConfig::default()).expect("start"),
        cfg,
    )
}

#[test]
fn single_request_roundtrip() {
    let (coord, cfg) = start();
    let resp = coord.generate(vec![1, 2, 3, 4], 6).expect("generate");
    assert_eq!(resp.tokens.len(), 6);
    assert_eq!(resp.finish_reason, FinishReason::MaxTokens);
    assert!(resp.tokens.iter().all(|&t| t >= 0 && (t as usize) < cfg.vocab));
    assert!(resp.ttft_s > 0.0 && resp.total_s >= resp.ttft_s);
    assert!(resp.modeled_accel_s > 0.0);
    coord.shutdown().unwrap();
}

#[test]
fn batched_requests_all_complete() {
    let (coord, cfg) = start();
    let mut rxs = Vec::new();
    let mut rng = Rng::new(7);
    for i in 0..6 {
        let prompt: Vec<i32> = (0..3 + i % 4)
            .map(|_| rng.below(cfg.vocab) as i32)
            .collect();
        let (_id, rx) = coord.submit_async(prompt, 5, 0.0).unwrap();
        rxs.push(rx);
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let r = rx.recv().expect("response");
        assert_eq!(r.tokens.len(), 5, "request {i}");
    }
    let (stats, sim) = coord.stats().unwrap();
    assert_eq!(stats.completed, 6);
    assert_eq!(stats.prefills, 6);
    // continuous batching actually batched: fewer decode steps than
    // 6 requests x 4 decode tokens (= 24 sequential steps)
    assert!(stats.decode_steps < 24, "decode_steps {}", stats.decode_steps);
    assert!(stats.mean_occupancy() > 1.0, "occupancy {}", stats.mean_occupancy());
    assert!(sim.seconds > 0.0 && sim.energy_j > 0.0);
    coord.shutdown().unwrap();
}

#[test]
fn deterministic_greedy_decoding() {
    let (coord, _) = start();
    let a = coord.generate(vec![5, 6, 7], 8).unwrap();
    let b = coord.generate(vec![5, 6, 7], 8).unwrap();
    assert_eq!(a.tokens, b.tokens);
    coord.shutdown().unwrap();

    // same prompt through a fresh coordinator with identical weights
    let (coord2, _) = start();
    let c = coord2.generate(vec![5, 6, 7], 8).unwrap();
    assert_eq!(a.tokens, c.tokens);
    coord2.shutdown().unwrap();
}

#[test]
fn context_exhaustion_terminates() {
    let (coord, cfg) = start();
    // ask for far more tokens than the context window holds
    let resp = coord
        .generate(vec![1; cfg.seq_len / 2], cfg.seq_len * 4)
        .unwrap();
    assert_eq!(resp.finish_reason, FinishReason::Length);
    assert!(resp.tokens.len() < cfg.seq_len * 4);
    coord.shutdown().unwrap();
}

#[test]
fn fill_all_policy_works() {
    let (p, _) = params();
    let coord = Coordinator::start(
        "test".into(),
        p,
        EngineConfig { policy: AdmitPolicy::FillAll, ..Default::default() },
    )
    .unwrap();
    let mut rxs = Vec::new();
    for _ in 0..4 {
        rxs.push(coord.submit_async(vec![9, 9], 4, 0.0).unwrap().1);
    }
    for rx in rxs {
        assert_eq!(rx.recv().unwrap().tokens.len(), 4);
    }
    coord.shutdown().unwrap();
}

#[test]
fn tcp_front_end_roundtrip() {
    use std::io::{BufRead, BufReader, Write};
    let (p, _) = params();
    let coord = Arc::new(
        Coordinator::start("test".into(), p, EngineConfig::default()).unwrap(),
    );
    let port = kllm::coordinator::serve_tcp(coord.clone(), 0).expect("tcp");
    let mut sock = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    sock.write_all(b"{\"prompt\": [1,2,3], \"max_new_tokens\": 4}\n")
        .unwrap();
    let mut line = String::new();
    BufReader::new(sock.try_clone().unwrap())
        .read_line(&mut line)
        .unwrap();
    let j = kllm::util::json::Json::parse(line.trim()).expect("json reply");
    assert_eq!(j.get("tokens").unwrap().as_arr().unwrap().len(), 4);
    // malformed request gets an error object, not a hang
    sock.write_all(b"{\"nope\": 1}\n").unwrap();
    let mut line2 = String::new();
    BufReader::new(sock.try_clone().unwrap())
        .read_line(&mut line2)
        .unwrap();
    assert!(line2.contains("error"));
}
