//! Integration over the full quantization pipeline WITHOUT PJRT: calibrate
//! -> learn codebooks -> quantize weights+tokens -> WAQ LUT-GEMM with
//! error compensation -> compare against exact f32 GEMM across methods.
//! (The artifact-backed accuracy pipeline is exercised by
//! runtime_integration.rs and the experiment registry.)

use kllm::gemm::{self, CartesianLut};
use kllm::quant::{self, OutlierCfg};
use kllm::tensor::Matrix;
use kllm::util::rng::Rng;

/// Simulated "layer": heavy-tailed activations against gaussian weights.
fn layer_case(rng: &mut Rng, k: usize, n: usize) -> (Vec<Vec<f32>>, Matrix) {
    let w = Matrix::random_normal(k, n, 1.0, rng);
    let toks = (0..24).map(|_| rng.heavy_tailed_vec(k, 0.01, 12.0)).collect();
    (toks, w)
}

fn gemm_rel_err(x: &[f32], w: &Matrix, approx: &[f32]) -> f64 {
    let exact = Matrix::from_vec(1, x.len(), x.to_vec()).matmul(w);
    let num: f64 = approx
        .iter()
        .zip(exact.row(0))
        .map(|(&a, &b)| ((a - b) as f64).powi(2))
        .sum::<f64>()
        .sqrt();
    num / exact.frob_norm().max(1e-12)
}

#[test]
fn full_waq_pipeline_beats_int_rtn_on_outlier_activations() {
    let mut rng = Rng::new(42);
    let (toks, w) = layer_case(&mut rng, 512, 128);
    let calib: Vec<&[f32]> = toks[..16].iter().map(|t| t.as_slice()).collect();
    let cfg = OutlierCfg { total_frac: 0.01 };

    // the paper's path
    let qw = quant::quantize_weights(&w, 4);
    let cb = quant::learn_act_codebook(&calib, None, 4, cfg);
    let lut = CartesianLut::build(&cb, &qw.codebook);

    // INT-WAQ RTN path (W4A4)
    let w_rtn = quant::rtn::fake_quant_weights(&w, 4);

    let mut kllm_err = 0.0;
    let mut rtn_err = 0.0;
    for x in &toks[16..] {
        let tok = quant::quantize_token(x, &cb, cfg);
        let out = gemm::execute_dual_branch(&tok, &qw, &lut);
        kllm_err += gemm_rel_err(x, &w, &out);

        let mut xq = x.clone();
        quant::rtn::fake_quant_token(&mut xq, 4);
        let out_rtn = Matrix::from_vec(1, xq.len(), xq).matmul(&w_rtn);
        rtn_err += gemm_rel_err(x, &w, out_rtn.row(0));
    }
    assert!(
        kllm_err < rtn_err * 0.75,
        "KLLM err {kllm_err:.4} should beat RTN err {rtn_err:.4} by a margin"
    );
}

#[test]
fn static_thresholds_worse_than_dynamic_under_shift() {
    // the Fig 3 mechanism as a numeric claim: calibrate thresholds on one
    // distribution, evaluate on a shifted one -> dynamic top-k compensates
    // better than static thresholds.
    let mut rng = Rng::new(7);
    let k = 512;
    let w = Matrix::random_normal(k, 64, 1.0, &mut rng);
    let calib: Vec<Vec<f32>> = (0..16).map(|_| rng.heavy_tailed_vec(k, 0.01, 8.0)).collect();
    let refs: Vec<&[f32]> = calib.iter().map(|t| t.as_slice()).collect();
    let cfg = OutlierCfg { total_frac: 0.02 };
    let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
    let qw = quant::quantize_weights(&w, 4);
    let lut = CartesianLut::build(&cb, &qw.codebook);
    let (lo, hi) = quant::outlier::calibrate_thresholds(&refs, cfg);

    // shifted eval distribution: 3x outlier magnitude
    let mut dyn_err = 0.0;
    let mut stat_err = 0.0;
    for _ in 0..8 {
        let x = rng.heavy_tailed_vec(k, 0.02, 24.0);
        let tok_d = quant::quantize_token(&x, &cb, cfg);
        let tok_s = quant::quantize_token_static(&x, &cb, lo, hi);
        dyn_err += gemm_rel_err(&x, &w, &gemm::execute_dual_branch(&tok_d, &qw, &lut));
        stat_err += gemm_rel_err(&x, &w, &gemm::execute_dual_branch(&tok_s, &qw, &lut));
    }
    // static thresholds still catch the big shifted outliers, but dynamic
    // guarantees exactly-k coverage; allow equality margin
    assert!(
        dyn_err <= stat_err * 1.1,
        "dynamic {dyn_err:.4} vs static {stat_err:.4}"
    );
}

#[test]
fn smoothquant_and_quarot_improve_over_rtn_with_outlier_channels() {
    let mut rng = Rng::new(9);
    let k = 256;
    let n = 64;
    let w = Matrix::random_normal(k, n, 1.0, &mut rng);
    // activations with two persistent outlier channels
    let mk_tok = |rng: &mut Rng| -> Vec<f32> {
        let mut x = rng.normal_vec(k, 1.0);
        x[17] *= 40.0;
        x[101] *= 25.0;
        x
    };
    let calib: Vec<Vec<f32>> = (0..16).map(|_| mk_tok(&mut rng)).collect();
    let mut absmax = vec![0.0f32; k];
    for t in &calib {
        for (c, &v) in t.iter().enumerate() {
            absmax[c] = absmax[c].max(v.abs());
        }
    }

    let w_rtn = quant::rtn::fake_quant_weights(&w, 4);
    let sm = quant::smoothquant::smooth_quantize(&w, &absmax, 0.5, 4);
    let w_rot = quant::quarot::quarot_quantize(&w, 4);

    let mut e_rtn = 0.0;
    let mut e_sm = 0.0;
    let mut e_rot = 0.0;
    for _ in 0..8 {
        let x = mk_tok(&mut rng);
        // RTN
        let mut xq = x.clone();
        quant::rtn::fake_quant_token(&mut xq, 4);
        e_rtn += gemm_rel_err(&x, &w, Matrix::from_vec(1, k, xq).matmul(&w_rtn).row(0));
        // SmoothQuant
        let mut xs: Vec<f32> = x.iter().zip(&sm.smooth).map(|(&v, &s)| v / s).collect();
        quant::rtn::fake_quant_token(&mut xs, 4);
        e_sm += gemm_rel_err(&x, &w, Matrix::from_vec(1, k, xs).matmul(&sm.weights).row(0));
        // QuaRot
        let mut xr = Matrix::from_vec(1, k, x.clone());
        xr.hadamard_rows();
        let mut xrv = xr.data.clone();
        quant::rtn::fake_quant_token(&mut xrv, 4);
        e_rot += gemm_rel_err(&x, &w, Matrix::from_vec(1, k, xrv).matmul(&w_rot).row(0));
    }
    assert!(e_sm < e_rtn, "smoothquant {e_sm:.4} !< rtn {e_rtn:.4}");
    assert!(e_rot < e_rtn, "quarot {e_rot:.4} !< rtn {e_rtn:.4}");
}

#[test]
fn orizuru_drives_the_same_compensation_as_reference_detector() {
    let mut rng = Rng::new(11);
    let k = 300;
    let w = Matrix::random_normal(k, 32, 1.0, &mut rng);
    let calib: Vec<Vec<f32>> = (0..8).map(|_| rng.heavy_tailed_vec(k, 0.02, 10.0)).collect();
    let refs: Vec<&[f32]> = calib.iter().map(|t| t.as_slice()).collect();
    let cfg = OutlierCfg { total_frac: 0.02 };
    let cb = quant::learn_act_codebook(&refs, None, 4, cfg);
    let qw = quant::quantize_weights(&w, 4);
    let lut = CartesianLut::build(&cb, &qw.codebook);

    let x = rng.heavy_tailed_vec(k, 0.02, 10.0);
    let tok_ref = quant::quantize_token(&x, &cb, cfg);
    // rebuild the token using Orizuru as the detector (the hardware path)
    let k_side = cfg.k_per_side(k);
    let hw_idx = kllm::orizuru::detect_outliers(&x, k_side);
    let ref_idx: Vec<u32> = tok_ref.outliers.iter().map(|&(c, _, _)| c).collect();
    assert_eq!(hw_idx, ref_idx);
    let out = gemm::execute_dual_branch(&tok_ref, &qw, &lut);
    assert_eq!(out.len(), 32);
}
