//! Scheduler net: the chunked iteration-level scheduler vs the phased
//! burst loop. Everything runs in the default (featureless) build on the
//! native datapath (`Manifest::synthetic`, no artifacts).
//!
//! What is pinned here:
//!   * **Parity** — `--sched chunked` produces bit-identical greedy token
//!     streams to `--sched burst` at every chunk size (1, 7, 16, 64, and
//!     0 = auto-budget), across native-packed, native-sharded(3), and
//!     native-spec backends, prefix cache off and on — including a
//!     prompt longer than one chunk that forks a shared prefix so
//!     copy-on-write fires while the fork is still mid-chunk. Parity
//!     covers *sampled* streams too: temperature draws come from a
//!     per-request RNG seeded at admission, so stochastic output is a
//!     pure function of (engine seed, request id) — not of scheduling.
//!   * **Liveness/accounting property** — random interleavings of
//!     submit/step/abort/drain with mixed long/short prompts answer
//!     every request exactly once, never starve in-flight decodes while
//!     long prompts chunk through prefill, keep the paged-allocator
//!     invariants mid-flight, and leak zero KV blocks after drain —
//!     across both schedulers × `--kv-bits {32,4}` × queue caps.
//!   * **Regressions** — a deadline expiring *between chunks* answers
//!     `DeadlineExpired` before any token and reclaims the half-filled
//!     slot; a `ChaosBackend` fault during a chunk aborts only the
//!     chunking request while co-resident decodes keep streaming.
//!
//! Parity grid note: at `--kv-bits < 32` with the prefix cache *off*,
//! burst admission runs the dense FP32 prefill while chunked is
//! necessarily paged (the tail attention reads the quantized cache), so
//! first-token logits can legitimately differ between the two routes.
//! The grid therefore exercises quantized KV where both schedulers share
//! the paged route: prefix cache on (any backend), or native-spec
//! (whose admission is always paged). At FP32 the paged gathers
//! reproduce the dense accumulation order, so every route is compared.

use std::collections::HashMap;

use kllm::coordinator::{
    AdmitPolicy, BackendSpec, ChaosBackend, ChaosCfg, Engine, EngineConfig, FinishReason,
    NativeCfg, NativeWaqBackend, Request, SchedPolicy, ShardedWaqBackend, SpeculativeBackend,
};
use kllm::gemm::WaqBackend;
use kllm::kvcache::KvBits;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::util::rng::Rng;

fn tiny_cfg(decode_batch: usize) -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        seq_len: 16,
        batch: 1,
        decode_batch,
        head_dim: 16,
        d_ff: 128,
        n_linears: 8,
    }
}

fn native_backend(cfg: ModelCfg) -> NativeWaqBackend {
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    NativeWaqBackend::new(
        &manifest,
        &params,
        NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() },
    )
    .expect("native backend build")
}

fn sharded_backend(cfg: ModelCfg, shards: usize) -> ShardedWaqBackend {
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    ShardedWaqBackend::new(&manifest, &params, NativeCfg::default(), shards)
        .expect("sharded backend build")
}

/// Residual-damped params (as in `backend_parity.rs`) so the greedy
/// argmax develops margins and speculative rounds actually accept —
/// parity must hold at any acceptance rate, damping just makes the
/// accept/commit paths do real work under chunked scheduling too.
fn damped_params(manifest: &Manifest, damp: f32) -> ParamSet {
    let mut params = ParamSet::init(manifest, &mut Rng::new(42));
    for l in 0..manifest.model.n_layers {
        for name in [format!("l{l}.attn_out"), format!("l{l}.mlp_down")] {
            let idx = ParamSet::index_of(manifest, &name).expect("manifest param");
            let mut m = params.matrix(idx).expect("matrix");
            for v in m.data.iter_mut() {
                *v *= damp;
            }
            params.set_matrix(idx, &m).expect("set matrix");
        }
    }
    params
}

fn spec_backend(cfg: ModelCfg, ecfg: &EngineConfig) -> SpeculativeBackend {
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = damped_params(&manifest, 0.05);
    let target = NativeWaqBackend::new(
        &manifest,
        &params,
        NativeCfg { waq: WaqBackend::Packed, ..NativeCfg::default() },
    )
    .expect("target");
    SpeculativeBackend::new(
        &manifest,
        &params,
        Box::new(target),
        ecfg.mode,
        ecfg.spec_k,
        ecfg.draft_wbits,
    )
    .expect("speculative backend")
}

/// Seeded mixed stream: long prompts (several chunks at small budgets)
/// interleaved with short ones, submitted up front; drained to idle.
/// Returns `(id, tokens, finish_reason)` sorted by id.
fn mixed_stream(e: &mut Engine, vocab: usize) -> Vec<(u64, Vec<i32>, FinishReason)> {
    let mut rng = Rng::new(17);
    for id in 0..6u64 {
        let plen = if id % 2 == 0 { 9 + rng.below(4) } else { 1 + rng.below(3) };
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        e.submit(Request::new(id, prompt, 2 + rng.below(3)));
    }
    let mut out = e.run_to_completion().expect("run");
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| (r.id, r.tokens, r.finish_reason)).collect()
}

/// Like [`mixed_stream`] but sampled: even ids draw at temperature 0.8,
/// odd ids at 1.5, and id 5 stays greedy so both samplers and the
/// argmax path coexist in one decode batch.
fn sampled_stream(e: &mut Engine, vocab: usize) -> Vec<(u64, Vec<i32>, FinishReason)> {
    let mut rng = Rng::new(29);
    for id in 0..6u64 {
        let plen = if id % 2 == 0 { 9 + rng.below(4) } else { 1 + rng.below(3) };
        let prompt: Vec<i32> = (0..plen).map(|_| rng.below(vocab) as i32).collect();
        let mut r = Request::new(id, prompt, 2 + rng.below(3));
        r.temperature = match id {
            5 => 0.0,
            _ if id % 2 == 0 => 0.8,
            _ => 1.5,
        };
        e.submit(r);
    }
    let mut out = e.run_to_completion().expect("run");
    out.sort_by_key(|r| r.id);
    out.into_iter().map(|r| (r.id, r.tokens, r.finish_reason)).collect()
}

/// The paged-allocator invariant block (shared idiom with
/// `tests/backend_parity.rs`), valid whenever blocks are unaliased
/// (prefix cache off): no leaks, no double assignment, bounded tables.
fn check_paged_invariants(e: &Engine) {
    let kv = e.kv();
    let c = kv.cache();
    let cfg = &kv.cfg;
    let bt = c.block_tokens();
    let mut seen = std::collections::HashSet::new();
    let mut listed = 0usize;
    for slot in 0..cfg.decode_batch {
        for l in 0..cfg.n_layers {
            let written = c.written(l, slot);
            let blocks = c.slot_blocks(l, slot);
            assert!(written <= cfg.seq_len, "written out of bounds");
            assert_eq!(
                blocks.len(),
                written.div_ceil(bt),
                "table covers exactly the written positions"
            );
            if kv.position(slot).is_none() {
                assert_eq!(written, 0, "freed slot still has rows");
            }
            for &b in blocks {
                assert!((b as usize) < c.capacity_blocks(), "block id beyond pool");
                assert!(seen.insert(b), "block {b} assigned twice");
            }
            listed += blocks.len();
        }
    }
    assert_eq!(listed, c.in_use_blocks(), "block leak: listed != in-use");
}

// ---------------------------------------------------------------------------
// parity: chunked == burst token streams
// ---------------------------------------------------------------------------

/// Tentpole acceptance: chunked scheduling is bit-exact per request with
/// the burst loop at every chunk size — including 1 (a long prompt
/// crosses many steps) and 0 (auto-budget, EWMA-sized) — with the
/// prefix cache off and on, on the packed native backend at FP32 KV.
#[test]
fn chunked_bit_exact_with_burst_across_chunk_sizes_and_prefix() {
    let cfg = tiny_cfg(3);
    for prefix_cache in [false, true] {
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            prefix_cache,
            ..Default::default()
        };
        let want = {
            let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
            mixed_stream(&mut e, cfg.vocab)
        };
        for chunk in [1usize, 7, 16, 64, 0] {
            let ecfg = EngineConfig {
                sched: SchedPolicy::Chunked,
                prefill_chunk: chunk,
                ..ecfg.clone()
            };
            let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
            assert_eq!(e.sched(), SchedPolicy::Chunked, "paged backend must not fall back");
            let got = mixed_stream(&mut e, cfg.vocab);
            assert_eq!(
                got, want,
                "prefix={prefix_cache} chunk={chunk}: chunked diverged from burst"
            );
            assert_eq!(e.stats.prefills, 6, "every request must finish its prefill");
            assert_eq!(e.stats.prefill_failures + e.stats.step_failures, 0);
            assert_eq!(e.active_count(), 0);
            assert!(
                e.stats.decode_lat.count() > 0,
                "inter-token histogram must record under chunked"
            );
            if !prefix_cache {
                assert_eq!(e.kv().cache().in_use_blocks(), 0, "chunk={chunk} leaked blocks");
            }
        }
    }
}

/// Parity beyond greedy: sampled (temperature > 0) token streams are
/// bit-identical between burst and chunked at every chunk size, because
/// each request draws from its own RNG stream seeded at admission from
/// (engine seed, request id) — never from a shared engine-wide stream
/// whose draw order would depend on scheduling. Two engines with the
/// same seed reproduce the streams exactly; a different engine seed
/// must change them, proving the sampler is live and not argmaxing.
#[test]
fn chunked_sampled_streams_bit_exact_with_burst() {
    let cfg = tiny_cfg(3);
    for prefix_cache in [false, true] {
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            prefix_cache,
            seed: 0xD1CE,
            ..Default::default()
        };
        let want = {
            let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
            sampled_stream(&mut e, cfg.vocab)
        };
        // same seed, same scheduler: a fresh engine reproduces the draws
        {
            let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
            assert_eq!(
                sampled_stream(&mut e, cfg.vocab),
                want,
                "prefix={prefix_cache}: same-seed re-run diverged"
            );
        }
        for chunk in [1usize, 7, 0] {
            let ecfg = EngineConfig {
                sched: SchedPolicy::Chunked,
                prefill_chunk: chunk,
                ..ecfg.clone()
            };
            let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
            let got = sampled_stream(&mut e, cfg.vocab);
            assert_eq!(
                got, want,
                "prefix={prefix_cache} chunk={chunk}: sampled stream diverged from burst"
            );
        }
        // a different engine seed must reroute at least one sampled draw
        let other = {
            let ecfg = EngineConfig { seed: 0xBEEF, ..ecfg.clone() };
            let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
            sampled_stream(&mut e, cfg.vocab)
        };
        assert_ne!(other, want, "engine seed has no effect on sampling");
    }
}

/// The same parity bar across the other backends: tensor-parallel
/// sharded (3 shards) and speculative (draft + stacked verification),
/// at FP32 and — where burst and chunked share the paged storage route
/// (see the module doc) — 4-bit KV.
#[test]
fn chunked_bit_exact_with_burst_on_sharded_and_spec_backends() {
    let cfg = tiny_cfg(3);
    // (backend, kv_bits, prefix_cache, chunk sizes)
    let sharded_grid: &[(KvBits, bool, &[usize])] = &[
        (KvBits::Fp32, false, &[1, 16]),
        (KvBits::Fp32, true, &[7]),
        (KvBits::B4, true, &[7]),
    ];
    for &(kv_bits, prefix_cache, chunks) in sharded_grid {
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            backend: BackendSpec::NativeSharded,
            shards: 3,
            kv_bits,
            prefix_cache,
            ..Default::default()
        };
        let want = {
            let mut e = Engine::new(Box::new(sharded_backend(cfg, 3)), &ecfg);
            mixed_stream(&mut e, cfg.vocab)
        };
        for &chunk in chunks {
            let ecfg = EngineConfig {
                sched: SchedPolicy::Chunked,
                prefill_chunk: chunk,
                ..ecfg.clone()
            };
            let mut e = Engine::new(Box::new(sharded_backend(cfg, 3)), &ecfg);
            let got = mixed_stream(&mut e, cfg.vocab);
            assert_eq!(
                got, want,
                "sharded kv={kv_bits} prefix={prefix_cache} chunk={chunk} diverged"
            );
            assert!(e.stats.host_shard_crit_s > 0.0, "shard critical path not measured");
        }
    }
    // native-spec admission is always paged, so burst and chunked share
    // the storage route at every kv-bits — including 4-bit, prefix off
    let spec_grid: &[(KvBits, bool, &[usize])] = &[
        (KvBits::Fp32, false, &[1, 16]),
        (KvBits::Fp32, true, &[7]),
        (KvBits::B4, false, &[7]),
    ];
    for &(kv_bits, prefix_cache, chunks) in spec_grid {
        let ecfg = EngineConfig {
            policy: AdmitPolicy::FillAll,
            backend: BackendSpec::NativeSpec,
            spec_k: 3,
            draft_wbits: 2,
            kv_bits,
            prefix_cache,
            ..Default::default()
        };
        let want = {
            let mut e = Engine::new(Box::new(spec_backend(cfg, &ecfg)), &ecfg);
            mixed_stream(&mut e, cfg.vocab)
        };
        for &chunk in chunks {
            let ecfg = EngineConfig {
                sched: SchedPolicy::Chunked,
                prefill_chunk: chunk,
                ..ecfg.clone()
            };
            let mut e = Engine::new(Box::new(spec_backend(cfg, &ecfg)), &ecfg);
            let got = mixed_stream(&mut e, cfg.vocab);
            assert_eq!(
                got, want,
                "spec kv={kv_bits} prefix={prefix_cache} chunk={chunk} diverged"
            );
            assert!(e.stats.spec_rounds > 0, "no speculative rounds ran under chunked");
        }
    }
}

/// A prompt longer than one chunk forks a shared prefix mid-chunk: A's
/// 12-token prompt is indexed, then B reuses its first 8 tokens and
/// diverges — B's first uncached append lands in the *aliased* block, so
/// copy-on-write fires while B still has chunks left to prefill. The
/// fork's token stream must match burst's exactly, at FP32 and 4-bit KV.
#[test]
fn chunked_cow_fork_mid_chunk_matches_burst() {
    let cfg = tiny_cfg(2);
    let shared: Vec<i32> = (0..12).map(|t| 5 + t).collect();
    let forked: Vec<i32> =
        shared[..8].iter().copied().chain([60, 61, 62, 63]).collect();
    for kv_bits in [KvBits::Fp32, KvBits::B4] {
        let run = |sched: SchedPolicy, chunk: usize| {
            let ecfg = EngineConfig {
                policy: AdmitPolicy::FillAll,
                prefix_cache: true,
                kv_bits,
                sched,
                prefill_chunk: chunk,
                ..Default::default()
            };
            let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
            // phase 1: index the shared prompt
            e.submit(Request::new(0, shared.clone(), 3));
            let mut out = e.run_to_completion().expect("phase 1");
            // phase 2: the fork, plus a short co-resident decode
            e.submit(Request::new(1, forked.clone(), 3));
            e.submit(Request::new(2, vec![7, 9], 3));
            out.extend(e.run_to_completion().expect("phase 2"));
            out.sort_by_key(|r| r.id);
            let hits = e.stats.prefix_hits;
            let reused = e.stats.prefix_blocks_reused;
            (
                out.into_iter().map(|r| (r.id, r.tokens, r.finish_reason)).collect::<Vec<_>>(),
                hits,
                reused,
            )
        };
        let (want, want_hits, _) = run(SchedPolicy::Burst, 0);
        for chunk in [2usize, 3] {
            let (got, hits, reused) = run(SchedPolicy::Chunked, chunk);
            assert_eq!(got, want, "kv={kv_bits} chunk={chunk}: COW fork diverged");
            assert_eq!(hits, want_hits, "prefix index must serve the fork identically");
            assert!(hits >= 1, "the fork never hit the prefix index");
            assert!(reused >= 1, "no aliased blocks — COW was never armed");
        }
    }
}

// ---------------------------------------------------------------------------
// liveness / accounting property
// ---------------------------------------------------------------------------

/// Random interleavings of submit / step / abort_all / drain, mixed
/// long/short prompts (long ones span several chunks at budget 2), a
/// sprinkle of already-expired deadlines, across both schedulers ×
/// {FP32, 4-bit} KV × queue caps {unbounded, 2}:
///   * every submitted request is answered exactly once (step results,
///     immediate rejections, and abort responses combined);
///   * whenever decoding slots exist before a step, that step generates
///     tokens — long prefills cannot starve in-flight decodes;
///   * the paged-allocator invariants hold after every step;
///   * after the final drain the block pool is empty.
#[test]
fn prop_random_interleavings_exactly_once_no_starvation_no_leaks() {
    let cfg = tiny_cfg(3);
    for sched in [SchedPolicy::Burst, SchedPolicy::Chunked] {
        for kv_bits in [KvBits::Fp32, KvBits::B4] {
            for queue_cap in [0usize, 2] {
                for seed in 0..3u64 {
                    let label = format!(
                        "sched={sched} kv={kv_bits} cap={queue_cap} seed={seed}"
                    );
                    let ecfg = EngineConfig {
                        policy: AdmitPolicy::FillAll,
                        kv_bits,
                        queue_cap,
                        sched,
                        prefill_chunk: 2,
                        ..Default::default()
                    };
                    let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
                    let mut rng = Rng::new(0xA11CE ^ seed);
                    let mut answered: HashMap<u64, u32> = HashMap::new();
                    let record = |answered: &mut HashMap<u64, u32>, id: u64| {
                        *answered.entry(id).or_insert(0) += 1;
                    };
                    let mut next_id = 0u64;
                    for _ in 0..40 {
                        match rng.below(8) {
                            0..=3 => {
                                let plen = if rng.below(3) == 0 {
                                    9 + rng.below(4)
                                } else {
                                    1 + rng.below(3)
                                };
                                let prompt: Vec<i32> =
                                    (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
                                let mut r =
                                    Request::new(next_id, prompt, 1 + rng.below(3));
                                if rng.below(10) == 0 {
                                    r = r.with_deadline_ms(0);
                                }
                                next_id += 1;
                                if let Some(resp) = e.try_submit(r) {
                                    assert_eq!(resp.finish_reason, FinishReason::Rejected);
                                    record(&mut answered, resp.id);
                                }
                            }
                            4..=6 => {
                                let decoding =
                                    e.active_count().saturating_sub(e.prefilling_count());
                                let before = e.stats.generated_tokens;
                                for resp in e.step().expect("step") {
                                    record(&mut answered, resp.id);
                                }
                                if decoding > 0 {
                                    assert!(
                                        e.stats.generated_tokens > before,
                                        "{label}: decodes starved by prefill work"
                                    );
                                }
                                check_paged_invariants(&e);
                            }
                            _ => {
                                for resp in e.abort_all() {
                                    record(&mut answered, resp.id);
                                }
                                assert_eq!(e.active_count(), 0, "{label}: abort left slots");
                                check_paged_invariants(&e);
                            }
                        }
                    }
                    for resp in e.run_to_completion().expect("drain") {
                        record(&mut answered, resp.id);
                    }
                    assert_eq!(
                        answered.len() as u64,
                        next_id,
                        "{label}: {} of {next_id} requests answered",
                        answered.len()
                    );
                    for (id, n) in &answered {
                        assert_eq!(*n, 1, "{label}: request {id} answered {n} times");
                    }
                    assert_eq!(
                        e.kv().cache().in_use_blocks(),
                        0,
                        "{label}: KV blocks leaked after drain"
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// regressions: deadlines between chunks, chaos mid-chunk
// ---------------------------------------------------------------------------

/// A deadline that expires *between chunks* (mid-prefill, before any
/// token was sampled) must answer `DeadlineExpired` with an empty
/// stream and reclaim the partially-filled KV slot — on the real native
/// datapath, not just the scripted engine fixture.
#[test]
fn chunked_deadline_expires_between_chunks_reclaims_slot() {
    let cfg = tiny_cfg(2);
    let ecfg = EngineConfig {
        sched: SchedPolicy::Chunked,
        prefill_chunk: 1,
        ..Default::default()
    };
    let mut e = Engine::new(Box::new(native_backend(cfg)), &ecfg);
    let prompt: Vec<i32> = (0..10).map(|t| 20 + t).collect();
    e.submit(Request::new(0, prompt, 4).with_deadline_ms(40));
    let first = e.step().expect("first chunk");
    assert!(first.is_empty(), "one 1-row chunk cannot finish a 10-token prefill");
    assert_eq!(e.prefilling_count(), 1, "request must be parked mid-prefill");
    std::thread::sleep(std::time::Duration::from_millis(60));
    let done = e.step().expect("sweep step");
    assert_eq!(done.len(), 1);
    assert_eq!(done[0].finish_reason, FinishReason::DeadlineExpired);
    assert!(done[0].tokens.is_empty(), "expired before the first token");
    assert_eq!(e.stats.expired, 1);
    assert_eq!(e.stats.prefills, 0, "prefill never completed");
    assert_eq!(e.active_count(), 0);
    assert_eq!(e.prefilling_count(), 0);
    assert_eq!(e.kv().cache().in_use_blocks(), 0, "half-filled slot not reclaimed");
    // the engine keeps serving afterwards
    e.submit(Request::new(1, vec![3, 4], 2));
    let rest = e.run_to_completion().expect("post-expiry service");
    assert_eq!(rest.len(), 1);
    assert!(rest[0].finish_reason.is_natural(), "{:?}", rest[0].finish_reason);
}

/// A `ChaosBackend` fault landing on a chunk aborts only the chunking
/// request: the co-resident decode keeps streaming and completes
/// naturally, and the engine serves new work afterwards.
///
/// Draw arithmetic (contractual, see `chaos.rs`): the trait-default
/// `schedule` draws once per step with chunks (`prefill_paged`) and
/// three times per step with active decodes; skipped phases draw
/// nothing. Step 1 is chunk-only (draw #1 must pass), step 2 is B's
/// chunk (draw #2 must fault) plus A's decode (draws #3–5, rates 0).
/// The seed is searched, not hard-coded, so the test documents its own
/// requirement on the fault pattern.
#[test]
fn chaos_chunk_fault_aborts_only_the_chunking_request() {
    let cfg = tiny_cfg(2);
    let seed = (0u64..)
        .find(|&s| {
            let mut r = Rng::new(s);
            let pass = r.f64();
            let fault = r.f64();
            pass >= 0.5 && fault < 0.5
        })
        .expect("some seed passes then faults");
    let mut ccfg = ChaosCfg::uniform(seed, 0.0);
    ccfg.prefill_err_rate = 0.5;
    ccfg.fault_budget = 1; // exactly one hard error, then healthy
    let chaos = ChaosBackend::new(Box::new(native_backend(cfg)), ccfg);
    let counters = chaos.counters();
    let ecfg = EngineConfig {
        sched: SchedPolicy::Chunked,
        prefill_chunk: 16,
        ..Default::default()
    };
    let mut e = Engine::new(Box::new(chaos), &ecfg);

    e.submit(Request::new(0, vec![1, 2, 3], 4));
    let s1 = e.step().expect("step 1: A's chunk passes");
    assert!(s1.is_empty());
    assert_eq!(e.active_count(), 1, "A promoted to decode");
    assert_eq!(e.prefilling_count(), 0);

    e.submit(Request::new(1, vec![4, 5, 6], 4));
    let s2 = e.step().expect("step 2: B's chunk faults, A decodes");
    assert_eq!(s2.len(), 1, "exactly the chunking request is answered");
    assert_eq!(s2[0].id, 1);
    assert_eq!(s2[0].finish_reason, FinishReason::Aborted);
    assert!(s2[0].tokens.is_empty());
    assert_eq!(counters.prefill_errs(), 1, "the injected fault must have landed");
    assert_eq!(e.stats.prefill_failures, 1);
    assert_eq!(e.active_count(), 1, "A survives B's chunk fault");

    let rest = e.run_to_completion().expect("A drains");
    assert_eq!(rest.len(), 1);
    assert_eq!(rest[0].id, 0);
    assert_eq!(rest[0].tokens.len(), 4, "A's stream is unharmed");
    assert!(rest[0].finish_reason.is_natural());
    assert_eq!(e.stats.step_failures, 0, "the decode path never faulted");
    assert_eq!(e.kv().cache().in_use_blocks(), 0);

    // fault budget spent: the engine serves new requests cleanly
    e.submit(Request::new(2, vec![9, 8, 7], 3));
    let post = e.run_to_completion().expect("post-fault service");
    assert_eq!(post.len(), 1);
    assert!(post[0].finish_reason.is_natural());
}
