//! Serving-robustness soak suite (runs in the default featureless
//! build): a deterministic chaos soak over the engine, the engine-death
//! regression through the coordinator, an exactly-once terminal-response
//! property over random submit/expire/reject/abort interleavings, and a
//! quick multi-client TCP soak ending in a graceful drain. CI runs this
//! file directly (`cargo test --test soak`); the heavier heavy-tailed
//! trace that emits BENCH_soak.json lives in `benches/soak.rs`.

use std::time::Duration;

use kllm::coordinator::{
    AdmitPolicy, BackendSpec, ChaosBackend, ChaosCfg, Coordinator, Engine, EngineConfig,
    FinishReason, NativeCfg, NativeWaqBackend, PjrtBackend, Request, Response, TcpCfg,
};
use kllm::gemm::WaqBackend;
use kllm::kvcache::KvBits;
use kllm::runtime::artifacts::ModelCfg;
use kllm::runtime::{Manifest, ParamSet};
use kllm::sim::OasisMode;
use kllm::util::check::Check;
use kllm::util::json::Json;
use kllm::util::rng::Rng;

fn tiny_cfg(decode_batch: usize) -> ModelCfg {
    ModelCfg {
        vocab: 64,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        seq_len: 16,
        batch: 1,
        decode_batch,
        head_dim: 16,
        d_ff: 128,
        n_linears: 8,
    }
}

fn native_backend(cfg: ModelCfg) -> NativeWaqBackend {
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    NativeWaqBackend::new(&manifest, &params, NativeCfg::default()).expect("native backend")
}

fn stub_backend(cfg: ModelCfg) -> PjrtBackend {
    PjrtBackend::stub(cfg, WaqBackend::Packed, OasisMode::a4())
}

/// The paged-allocator invariant block (same checks as
/// `tests/backend_parity.rs`): no leaks, no double assignment, bounded
/// tables — run against the live engine mid-soak.
fn check_paged_invariants(e: &Engine) {
    let kv = e.kv();
    let c = kv.cache();
    let cfg = &kv.cfg;
    let bt = c.block_tokens();
    let mut seen = std::collections::HashSet::new();
    let mut listed = 0usize;
    for slot in 0..cfg.decode_batch {
        for l in 0..cfg.n_layers {
            let written = c.written(l, slot);
            let blocks = c.slot_blocks(l, slot);
            assert!(written <= cfg.seq_len, "written out of bounds");
            assert_eq!(
                blocks.len(),
                written.div_ceil(bt),
                "table covers exactly the written positions"
            );
            if kv.position(slot).is_none() {
                assert_eq!(written, 0, "freed slot still has rows");
            }
            for &b in blocks {
                assert!((b as usize) < c.capacity_blocks(), "block id beyond pool");
                assert!(seen.insert(b), "block {b} assigned twice");
            }
            listed += blocks.len();
        }
    }
    assert_eq!(listed, c.in_use_blocks(), "block leak: listed != in-use");
}

/// Every terminal response reduced to its observable outcome.
type Signature = Vec<(u64, &'static str, Vec<i32>)>;

/// Counter snapshot compared across identical-seed runs (the wall-clock
/// stats fields are excluded on purpose — they can never be equal).
type Counters = (u64, u64, u64, u64, u64);

/// One deterministic chaos soak: a seeded submit/step schedule over a
/// chaos-wrapped native backend with a bounded queue, already-expired
/// deadlines on every 5th request, and a guaranteed admission-overflow
/// burst at the end. Returns the outcome signature + stat counters, and
/// asserts the structural invariants (exactly-once, leak-free) inline.
fn run_chaos_soak(seed: u64) -> (Signature, Counters) {
    const QUEUE_CAP: usize = 4;
    let cfg = tiny_cfg(4);
    let ecfg = EngineConfig {
        policy: AdmitPolicy::FillAll,
        kv_bits: KvBits::B4,
        queue_cap: QUEUE_CAP,
        ..Default::default()
    };
    let chaos = ChaosCfg {
        seed: 0xC4A05 ^ seed,
        prefill_err_rate: 0.05,
        decode_err_rate: 0.05,
        nan_rate: 0.10,
        spike_rate: 0.10,
        spike_s: 1e-4,
        fault_budget: u64::MAX,
    };
    let mut e = Engine::new(
        Box::new(ChaosBackend::new(Box::new(native_backend(cfg)), chaos)),
        &ecfg,
    );
    let mut rng = Rng::new(seed);
    let mut terminals: Vec<Response> = Vec::new();
    let mut submitted = 0u64;
    for _round in 0..30 {
        for _ in 0..(1 + rng.below(2)) {
            let id = submitted;
            submitted += 1;
            let plen = 1 + rng.below(6);
            let prompt: Vec<i32> = (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
            let mut req = Request::new(id, prompt, 1 + rng.below(5));
            // every 5th request arrives already past its deadline: it must
            // terminate DeadlineExpired from the queue sweep (or Rejected
            // when the queue is at cap) without ever reaching the backend
            if id % 5 == 0 {
                req = req.with_deadline_ms(0);
            }
            if let Some(reject) = e.try_submit(req) {
                terminals.push(reject);
            }
        }
        for _ in 0..(1 + rng.below(2)) {
            if e.has_work() {
                terminals.extend(e.step().expect("chaos faults must be contained"));
                check_paged_invariants(&e);
            }
        }
    }
    while e.has_work() {
        terminals.extend(e.step().expect("backlog step"));
        check_paged_invariants(&e);
    }
    // the queue is now empty: QUEUE_CAP + 3 back-to-back submits must
    // yield exactly 3 immediate structured rejections
    let mut overflow_rejects = 0;
    for _ in 0..QUEUE_CAP + 3 {
        let id = submitted;
        submitted += 1;
        if let Some(reject) = e.try_submit(Request::new(id, vec![1, 2, 3], 4)) {
            assert_eq!(reject.finish_reason, FinishReason::Rejected);
            assert!(reject.tokens.is_empty());
            terminals.push(reject);
            overflow_rejects += 1;
        }
    }
    assert_eq!(overflow_rejects, 3, "cap overflow must reject exactly the excess");
    while e.has_work() {
        terminals.extend(e.step().expect("final step"));
        check_paged_invariants(&e);
    }

    // exactly-once: every submitted id has exactly one terminal response
    assert_eq!(terminals.len() as u64, submitted, "one terminal response per request");
    let mut ids: Vec<u64> = terminals.iter().map(|r| r.id).collect();
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len() as u64, submitted, "no id answered twice");
    assert_eq!(e.kv().cache().in_use_blocks(), 0, "KV blocks leaked after soak");
    assert_eq!(e.active_count(), 0);
    assert_eq!(e.pending(), 0);

    // terminal classification must reconcile with the engine's counters
    let count = |f: fn(&FinishReason) -> bool| {
        terminals.iter().filter(|r| f(&r.finish_reason)).count() as u64
    };
    assert_eq!(count(|f| f.is_natural()), e.stats.completed);
    assert_eq!(count(|f| *f == FinishReason::Rejected), e.stats.rejected);
    assert_eq!(count(|f| *f == FinishReason::DeadlineExpired), e.stats.expired);
    assert!(e.stats.completed > 0, "soak must complete some requests");
    assert!(e.stats.expired > 0, "already-expired deadlines must show up");
    assert!(e.stats.rejected >= 3, "cap overflow rejections must be counted");

    let mut sig: Signature = terminals
        .iter()
        .map(|r| (r.id, r.finish_reason.name(), r.tokens.clone()))
        .collect();
    sig.sort();
    let counters = (
        e.stats.completed,
        e.stats.rejected,
        e.stats.expired,
        e.stats.step_failures,
        e.stats.prefill_failures,
    );
    (sig, counters)
}

/// The soak acceptance property: with chaos enabled, two identical-seed
/// runs resolve every request identically — same tokens, same finish
/// reasons, same fault counters — and a different seed actually changes
/// the trace (the determinism isn't vacuous).
#[test]
fn chaos_soak_is_deterministic_exactly_once_and_leak_free() {
    let (sig_a, counters_a) = run_chaos_soak(7);
    let (sig_b, counters_b) = run_chaos_soak(7);
    assert_eq!(sig_a, sig_b, "identical seeds must produce identical outcomes");
    assert_eq!(counters_a, counters_b, "identical seeds must produce identical counters");
    let (sig_c, _) = run_chaos_soak(8);
    assert_ne!(sig_a, sig_c, "a different seed must change the trace");
}

/// Satellite regression (engine-thread death): before fault containment,
/// one failing decode step killed the engine thread — every queued waiter
/// hung forever and all later submits were lost. Now the poisoned step
/// aborts only its in-flight burst, every waiter is answered, and the
/// engine keeps serving.
#[test]
fn chaos_step_fault_mid_burst_answers_every_waiter_and_engine_survives() {
    let cfg = tiny_cfg(4);
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let chaos = ChaosCfg {
        decode_err_rate: 1.0,
        fault_budget: 1,
        ..ChaosCfg::uniform(9, 0.0)
    };
    let coord = Coordinator::start_with_manifest(
        manifest,
        params,
        EngineConfig {
            backend: BackendSpec::Native(WaqBackend::Packed),
            policy: AdmitPolicy::FillAll,
            chaos: Some(chaos),
            ..Default::default()
        },
    )
    .expect("coordinator start");
    let mut rxs = Vec::new();
    for i in 0..3i32 {
        let (_, rx) = coord
            .submit_with(vec![1 + i, 2, 3], 4, 0.0, None)
            .expect("submit");
        rxs.push(rx);
    }
    let mut reasons = Vec::new();
    for rx in rxs {
        // recv_timeout so a regression shows up as a failure, not a hang
        let resp = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("every waiter must be answered after a step fault");
        reasons.push(resp.finish_reason);
    }
    assert!(
        reasons.contains(&FinishReason::Aborted),
        "the poisoned decode step must abort its in-flight burst: {reasons:?}"
    );
    // the engine thread survived: a fresh request completes normally
    // (fault budget 1 is spent, so chaos is transparent from here on)
    let r = coord
        .generate(vec![5, 6], 3)
        .expect("engine must keep serving after the contained fault");
    assert_eq!(r.finish_reason, FinishReason::MaxTokens);
    assert_eq!(r.tokens.len(), 3);
    let (stats, _) = coord.stats().expect("stats");
    assert_eq!(stats.step_failures, 1, "exactly one contained fault (budget 1)");
    coord.shutdown().expect("clean shutdown");
}

/// Exactly-once property over random interleavings of submit (with and
/// without deadlines), bounded admission, engine steps, mid-flight
/// aborts, and a final drain-style abort_all — under chaos, at a
/// quantized KV width, with the paged-allocator invariants checked after
/// every step. Extends the PR 4 burst stress test to the full
/// terminal-response state machine.
#[test]
fn prop_every_request_resolves_exactly_once_under_random_interleavings() {
    let cfg = tiny_cfg(4);
    Check::new(12).forall("exactly-once-terminal", |rng, case| {
        let ecfg = EngineConfig {
            policy: if case % 2 == 0 { AdmitPolicy::FillAll } else { AdmitPolicy::OnePerStep },
            kv_bits: if case % 3 == 0 { KvBits::Fp32 } else { KvBits::B4 },
            queue_cap: [0, 2, 5][case % 3],
            ..Default::default()
        };
        let chaos = ChaosCfg {
            fault_budget: 3,
            ..ChaosCfg::uniform(case as u64, 0.08)
        };
        let mut e = Engine::new(
            Box::new(ChaosBackend::new(Box::new(stub_backend(cfg)), chaos)),
            &ecfg,
        );
        let mut terminals: Vec<Response> = Vec::new();
        let mut submitted = 0u64;
        for _op in 0..40 {
            match rng.below(5) {
                // submit: deadlines are None, already-past, or far-future
                // (never "soon" — wall-clock races would break the test)
                0 | 1 => {
                    let id = submitted;
                    submitted += 1;
                    let plen = 1 + rng.below(5);
                    let prompt: Vec<i32> =
                        (0..plen).map(|_| rng.below(cfg.vocab) as i32).collect();
                    let mut req = Request::new(id, prompt, 1 + rng.below(4));
                    match rng.below(4) {
                        0 => req = req.with_deadline_ms(0),
                        1 => req = req.with_deadline_ms(3_600_000),
                        _ => {}
                    }
                    if let Some(reject) = e.try_submit(req) {
                        assert_eq!(reject.finish_reason, FinishReason::Rejected);
                        terminals.push(reject);
                    }
                }
                2 | 3 => {
                    if e.has_work() {
                        terminals.extend(e.step().expect("contained step"));
                        check_paged_invariants(&e);
                    }
                }
                _ => {
                    if rng.below(4) == 0 {
                        terminals.extend(e.abort_inflight());
                        check_paged_invariants(&e);
                    }
                }
            }
        }
        terminals.extend(e.abort_all());
        assert_eq!(
            terminals.len() as u64,
            submitted,
            "case {case}: every request resolves exactly once"
        );
        let mut ids: Vec<u64> = terminals.iter().map(|r| r.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len() as u64, submitted, "case {case}: no double answers");
        assert_eq!(e.kv().cache().in_use_blocks(), 0, "case {case}: leaked KV blocks");
        check_paged_invariants(&e);
    });
}

/// Quick multi-client TCP soak: every request line gets exactly one
/// parseable JSON reply (deadline-expired and completed alike), an
/// over-capacity connection gets a structured rejection, garbage input
/// gets a structured error, and the final graceful drain returns every
/// KV block with the listener counters merged into the report.
#[test]
fn tcp_soak_exactly_one_reply_per_line_then_graceful_drain() {
    use std::io::{BufRead, BufReader, Write};
    let cfg = tiny_cfg(4);
    let manifest = Manifest::synthetic("tiny", cfg);
    let params = ParamSet::init(&manifest, &mut Rng::new(42));
    let coord = std::sync::Arc::new(
        Coordinator::start_with_manifest(
            manifest,
            params,
            EngineConfig {
                backend: BackendSpec::Native(WaqBackend::Packed),
                policy: AdmitPolicy::FillAll,
                queue_cap: 8,
                ..Default::default()
            },
        )
        .expect("coordinator start"),
    );
    let tcp = TcpCfg { max_conns: 8, read_timeout: Some(Duration::from_secs(10)) };
    let port = kllm::coordinator::serve_tcp_with(coord.clone(), 0, tcp).expect("tcp");

    // phase 1: 4 concurrent clients x 5 requests; every 4th request
    // carries an already-expired deadline and must come back
    // `deadline_expired` with no tokens (clients each keep one request in
    // flight, so the depth-8 queue never rejects here)
    let mut handles = Vec::new();
    for c in 0..4u64 {
        handles.push(std::thread::spawn(move || {
            let mut sock = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
            let mut reader = BufReader::new(sock.try_clone().unwrap());
            let mut expired = 0usize;
            for i in 0..5u64 {
                let deadline =
                    if (c + i) % 4 == 0 { ", \"deadline_ms\": 0" } else { "" };
                let line = format!(
                    "{{\"prompt\": [{}, 2, 3], \"max_new_tokens\": 3{}}}\n",
                    1 + c, deadline
                );
                sock.write_all(line.as_bytes()).unwrap();
                let mut reply = String::new();
                reader.read_line(&mut reply).unwrap();
                let j = Json::parse(reply.trim()).expect("reply must be valid JSON");
                let reason = j.get("finish_reason").and_then(Json::as_str).unwrap();
                let ntok = j.get("tokens").unwrap().as_arr().unwrap().len();
                if deadline.is_empty() {
                    assert_eq!(reason, "max_tokens", "{reply}");
                    assert_eq!(ntok, 3, "{reply}");
                } else {
                    assert_eq!(reason, "deadline_expired", "{reply}");
                    assert_eq!(ntok, 0, "{reply}");
                    expired += 1;
                }
                assert_eq!(j.get("rejected").and_then(Json::as_bool), Some(false));
            }
            expired
        }));
    }
    let expired: usize = handles.into_iter().map(|h| h.join().expect("client")).sum();
    assert_eq!(expired, 5, "the (c + i) % 4 == 0 schedule expires exactly 5 requests");

    // phase 2: a --max-conns 1 listener on the same engine — while one
    // connection is held (its handler provably registered by a completed
    // roundtrip), the next connection gets a structured rejection line
    let capped = TcpCfg { max_conns: 1, read_timeout: Some(Duration::from_secs(10)) };
    let port1 = kllm::coordinator::serve_tcp_with(coord.clone(), 0, capped).expect("tcp capped");
    let mut held = std::net::TcpStream::connect(("127.0.0.1", port1)).unwrap();
    let mut held_reader = BufReader::new(held.try_clone().unwrap());
    held.write_all(b"{\"prompt\": [1], \"max_new_tokens\": 1}\n").unwrap();
    let mut reply = String::new();
    held_reader.read_line(&mut reply).unwrap();
    assert!(Json::parse(reply.trim()).is_ok(), "{reply}");
    let over = std::net::TcpStream::connect(("127.0.0.1", port1)).unwrap();
    let mut over_reply = String::new();
    BufReader::new(over).read_line(&mut over_reply).unwrap();
    let j = Json::parse(over_reply.trim()).expect("over-capacity reply is valid JSON");
    assert_eq!(j.get("rejected").and_then(Json::as_bool), Some(true), "{over_reply}");
    assert!(j.get("error").and_then(Json::as_str).is_some(), "{over_reply}");

    // phase 3: garbage input gets a structured, parseable error reply
    let mut garbage = std::net::TcpStream::connect(("127.0.0.1", port)).unwrap();
    let mut greader = BufReader::new(garbage.try_clone().unwrap());
    garbage.write_all(b"this is { not \"json\n").unwrap();
    let mut greply = String::new();
    greader.read_line(&mut greply).unwrap();
    let j = Json::parse(greply.trim()).expect("error reply must be valid JSON");
    assert!(j.get("error").and_then(Json::as_str).is_some(), "{greply}");

    // phase 4: graceful drain — every block returned, listener counters
    // merged into the final stats
    let report = coord.drain(Duration::from_secs(10)).expect("drain");
    assert_eq!(report.in_use_blocks, 0, "drain must return every KV block");
    assert_eq!(report.stats.completed, 16, "15 soak completions + the held request");
    assert_eq!(report.stats.expired, 5);
    assert_eq!(report.stats.conn_rejected, 1, "the over-capacity connection");
    assert_eq!(report.stats.accept_errors, 0);
    assert_eq!(report.stats.rejected, 0, "nothing hit the depth-8 queue cap");
}
